"""Declarative experiment-spec layer (repro.spec).

Pins the four guarantees the spec API makes:

  * ROUND-TRIP -- to_dict/from_dict and the TOML/JSON file forms are
    exact inverses (idempotent re-dump), for hand-built specs and for
    every bundled spec under examples/specs/.
  * STRICTNESS -- unknown sections/keys, bad enum strings, wrong value
    types, misplaced policy/algorithm knobs, and inconsistent cross-field
    combinations all raise SpecError (never silently ignored).
  * EQUIVALENCE -- the legacy simulate-CLI flag surface maps onto a spec
    whose built trajectory is bit-for-bit the historical one: the bundled
    golden spec reproduces tests/fixtures/golden_sync_trajectory.npz, and
    a --spec file run equals the equivalent legacy-flag run under both
    engines.
  * TOTALITY -- any spec that passes validation builds (hypothesis rule,
    optional as in the kernel tests).
"""
from __future__ import annotations

import dataclasses
import json
import pathlib

import numpy as np
import pytest

try:
    import hypothesis
    import hypothesis.strategies as st
except ImportError:
    hypothesis = None

from repro.launch import simulate
from repro.spec import (
    AlgorithmSpec,
    CodecSpec,
    EngineSpec,
    ExperimentSpec,
    FleetSpec,
    PolicySpec,
    SpecError,
    TaskSpec,
    sweep,
)

ROOT = pathlib.Path(__file__).resolve().parent.parent
SPECS_DIR = ROOT / "examples" / "specs"
GOLDEN_NPZ = ROOT / "tests" / "fixtures" / "golden_sync_trajectory.npz"
TRACE_CSV = ROOT / "tests" / "fixtures" / "device_trace.csv"

# a nontrivial spec touching every section (small enough to build fast)
FULL_SPEC = ExperimentSpec(
    name="test/full", seed=7,
    task=TaskSpec(kind="logreg", d=600, n=14, m=8),
    algorithm=AlgorithmSpec(name="fedepm", rho=0.5, k0=4, eps_dp=0.1,
                            sensitivity_clip=1.0),
    fleet=FleetSpec(kind="synthetic", latency="pareto", latency_alpha=1.4,
                    seed=3),
    policy=PolicySpec(name="async", buffer_size=3, max_concurrency=4,
                      staleness_exp=0.7),
    codec=CodecSpec(topk_frac=0.5, bits=8, error_feedback=True),
    engine=EngineSpec(name="eager", rounds=3))


# ---------------------------------------------------------------------------
# round-trip
# ---------------------------------------------------------------------------

def test_dict_roundtrip_exact():
    d = FULL_SPEC.to_dict()
    assert ExperimentSpec.from_dict(d) == FULL_SPEC
    # unset Optional fields are omitted, not serialized as null
    assert "deadline" not in d["policy"]
    assert "mu0" not in d["algorithm"]


@pytest.mark.parametrize("ext", [".toml", ".json"])
def test_file_roundtrip_idempotent(tmp_path, ext):
    p1, p2 = tmp_path / f"a{ext}", tmp_path / f"b{ext}"
    FULL_SPEC.dump(p1)
    loaded = ExperimentSpec.load(p1)
    assert loaded == FULL_SPEC
    loaded.dump(p2)
    assert p2.read_text() == p1.read_text()  # dump∘load is the identity


def test_bundled_specs_roundtrip(tmp_path):
    from repro.spec import load_sweep
    from repro.spec.serialize import read_spec_file

    files = sorted(SPECS_DIR.glob("*.toml"))
    assert len(files) >= 4, "bundled example specs went missing"
    swept = 0
    for f in files:
        if "sweep" in dict(read_spec_file(f)):
            # [sweep] grid files validate base + every expanded cell;
            # the [sweep] table itself is not part of the dataclass
            base, cells = load_sweep(f)
            assert len(cells) > 1 and len({c.name for c in cells}) \
                == len(cells), f.name
            swept += 1
            continue
        spec = ExperimentSpec.load(f)  # validates
        out = tmp_path / f.name
        spec.dump(out)
        assert ExperimentSpec.load(out) == spec, f.name
        jout = tmp_path / (f.stem + ".json")
        spec.dump(jout)
        assert ExperimentSpec.load(jout) == spec, f.name
    assert swept >= 1, "bundled [sweep] grid file went missing"


# ---------------------------------------------------------------------------
# strictness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d,msg", [
    ({"polcy": {"name": "sync"}}, "unknown spec section"),
    ({"policy": {"name": "sync", "bufer_size": 2}}, "unknown key"),
    ({"policy": {"name": "sync"}, "extra": 1}, "unknown spec section"),
    ({"task": {"d": "many"}}, "expected int"),
    ({"task": {"d": True}}, "expected int"),
    ({"engine": {"rounds": 2.5}}, "expected int"),
    ({"engine": 3}, "must be a table"),
])
def test_from_dict_rejects(d, msg):
    with pytest.raises(SpecError, match=msg):
        ExperimentSpec.from_dict(d)


@pytest.mark.parametrize("kw,msg", [
    # bad enum strings resolve through the registries
    ({"algorithm": AlgorithmSpec(name="sgd")}, "unknown name"),
    ({"policy": PolicySpec(name="semisync")}, "unknown name"),
    ({"task": TaskSpec(kind="vision")}, "unknown kind"),
    ({"fleet": FleetSpec(latency="gamma")}, "unknown latency model"),
    ({"engine": EngineSpec(name="turbo")}, "unknown name"),
    ({"codec": CodecSpec(name="zip")}, "unknown name"),
    ({"task": TaskSpec(kind="lm", arch="gpt-17")}, "unknown arch"),
    # knob ownership: never silently ignored
    ({"policy": PolicySpec(name="sync", buffer_size=4)}, "does not apply"),
    ({"policy": PolicySpec(name="deadline", deadline=0.1,
                           max_concurrency=2)}, "does not apply"),
    ({"policy": PolicySpec(name="async", deadline=0.1)}, "does not apply"),
    ({"algorithm": AlgorithmSpec(name="sfedavg", mu0=1.0)},
     "does not apply"),
    ({"algorithm": AlgorithmSpec(name="fedepm", prox_mu=1.0)},
     "does not apply"),
    ({"engine": EngineSpec(name="eager", chunk=4)}, "does not apply"),
    # range rules (the CLI enforces the same ones)
    ({"policy": PolicySpec(name="async", buffer_size=-1)}, "buffer_size"),
    ({"policy": PolicySpec(name="async", staleness_exp=-0.5)},
     "staleness_exp"),
    ({"policy": PolicySpec(name="async", max_concurrency=-2)},
     "max_concurrency"),
    ({"policy": PolicySpec(name="deadline", deadline=-1.0)}, "deadline"),
    ({"algorithm": AlgorithmSpec(name="fedepm", rho=0.0)}, "rho"),
    ({"codec": CodecSpec(bits=1)}, "bits"),
    ({"codec": CodecSpec(error_feedback=True)}, "lossy"),
    ({"fleet": FleetSpec(availability=0.0)}, "availability"),
    # cross-field rules
    ({"fleet": FleetSpec(kind="trace", trace_file=str(TRACE_CSV),
                         availability=0.5)}, "conflicts"),
    ({"fleet": FleetSpec(kind="synthetic",
                         trace_file=str(TRACE_CSV))}, "trace_file"),
    ({"task": TaskSpec(kind="logreg", arch="smollm-135m")}, "lm-task"),
    ({"task": TaskSpec(kind="lm")}, "requires arch"),
    ({"engine": EngineSpec(name="eager", terminate=True),
      "task": TaskSpec(kind="lm", arch="smollm-135m")}, "terminate"),
    ({"algorithm": AlgorithmSpec(name="fedepm", sampler="coverage"),
      "policy": PolicySpec(name="overselect")}, "uniform"),
])
def test_validate_rejects(kw, msg):
    base = dataclasses.replace(FULL_SPEC, policy=PolicySpec(name="sync"),
                               codec=CodecSpec())
    with pytest.raises(SpecError, match=msg):
        dataclasses.replace(base, **kw).validate()


@pytest.mark.parametrize("argv,msg", [
    (["--buffer-size", "-1", "--aggregation", "async"], "buffer-size"),
    (["--max-concurrency", "-1", "--aggregation", "async"],
     "max-concurrency"),
    (["--staleness-exp", "-0.5", "--aggregation", "async"],
     "staleness-exp"),
    (["--buffer-size", "4"], "only valid with"),
    (["--buffer-size", "0", "--aggregation", "deadline"],
     "only valid with"),
    (["--staleness-exp", "0.5", "--aggregation", "sync"],
     "only valid with"),
    (["--max-concurrency", "0", "--aggregation", "overselect"],
     "only valid with"),
    (["--deadline", "0.01", "--aggregation", "sync"], "does not apply"),
])
def test_cli_rejects(argv, msg, capsys):
    """The CLI enforces the spec layer's knob rules: negative async knobs
    and async-only flags under clocked policies are hard errors, not
    silently ignored."""
    with pytest.raises(SystemExit) as exc:
        simulate.main(argv + ["--m", "8", "--d", "500", "--rounds", "2",
                              "--quiet"])
    assert exc.value.code == 2
    assert msg in capsys.readouterr().err


def test_cli_spec_rejects_legacy_flags(capsys):
    """A legacy flag alongside --spec would be silently ignored, which
    the spec layer forbids -- off-default ones are hard errors."""
    spec_file = str(SPECS_DIR / "golden_sync.toml")
    for extra in (["--buffer-size", "8"], ["--topk", "0.25"],
                  ["--alg", "sfedavg"], ["--latency", "pareto"]):
        with pytest.raises(SystemExit) as exc:
            simulate.main(["--spec", spec_file, "--quiet"] + extra)
        assert exc.value.code == 2
        assert "cannot be combined with --spec" in capsys.readouterr().err
    # the documented overrides still compose
    assert simulate.main(["--spec", spec_file, "--quiet",
                          "--engine", "scan", "--rounds", "1",
                          "--seed", "1"]) == 0


def test_cli_nonpositive_deadline_means_infinite(tmp_path):
    """--deadline <= 0 means an infinite cutoff (the flag's documented
    semantics), equivalent to the sync wait-for-all policy."""
    outs = []
    for dl in ("-1", "0"):
        p = tmp_path / f"dl{dl}.json"
        assert simulate.main(["--aggregation", "deadline",
                              "--deadline", dl, "--latency", "pareto",
                              "--m", "8", "--d", "500", "--rounds", "2",
                              "--quiet", "--json", str(p)]) == 0
        outs.append(json.loads(p.read_text()))
    assert outs[0]["f_final"] == outs[1]["f_final"]
    assert outs[0]["stragglers_dropped"] == 0


def test_negative_seeds_rejected():
    with pytest.raises(SpecError, match="seed"):
        dataclasses.replace(FULL_SPEC, seed=-1).validate()
    with pytest.raises(SpecError, match="seed"):
        FULL_SPEC.replace(**{"fleet.seed": -2}).validate()
    with pytest.raises(SpecError, match="seed"):
        FULL_SPEC.replace(**{"task.seed": -3}).validate()


# ---------------------------------------------------------------------------
# equivalence: legacy flags <-> spec <-> golden trajectory
# ---------------------------------------------------------------------------

def test_golden_spec_matches_npz():
    """The bundled golden spec reproduces the pinned sync trajectory:
    state head/clock/PRNG key bit-for-bit, objective to the golden test's
    own tolerance (its stored values were computed un-jitted)."""
    golden = np.load(GOLDEN_NPZ)
    handle = ExperimentSpec.load(SPECS_DIR / "golden_sync.toml").build()
    objective, t_total, w_head = [], [], []
    for _ in range(2):
        handle.sim.step()
        objective.append(float(handle.objective(handle.sim.state.w_tau)))
        t_total.append(handle.sim.t)
        w_head.append(np.asarray(handle.sim.state.w_tau[:8]))
    np.testing.assert_allclose(objective, golden["objective"], rtol=1e-6)
    np.testing.assert_array_equal(t_total, golden["t_total"])
    np.testing.assert_allclose(np.stack(w_head), golden["w_tau_head"],
                               rtol=0, atol=1e-7)
    np.testing.assert_array_equal(np.asarray(handle.sim.state.key),
                                  golden["key_final"])
    assert int(handle.sim.state.k) == int(golden["k_final"])


def test_legacy_flags_equal_spec_file(tmp_path):
    """One scenario, three surfaces -- legacy flags, the mapped
    ExperimentSpec dumped to TOML and run via --spec, and --spec under the
    scan engine -- produce the same summary."""
    argv = ["--alg", "fedepm", "--aggregation", "deadline",
            "--deadline", "0.002", "--latency", "pareto",
            "--m", "8", "--d", "600", "--rounds", "4", "--seed", "3"]
    legacy_json = tmp_path / "legacy.json"
    assert simulate.main(argv + ["--quiet", "--json",
                                 str(legacy_json)]) == 0

    import argparse
    args = argparse.Namespace(
        alg="fedepm", aggregation="deadline", deadline=0.002,
        latency="pareto", m=8, d=600, n=14, rounds=4, seed=3,
        rho=0.5, k0=8, eps=0.0, topk=1.0, bits=0, error_feedback=False,
        quant_impl="ref", engine="eager", terminate=False,
        overselect=1.5, deadline_slack=2.0, ewma_beta=0.3,
        buffer_size=None, staleness_exp=None, max_concurrency=None,
        latency_sigma=0.5, latency_alpha=1.2, availability=1.0,
        trace_file=None)
    spec = simulate.spec_from_args(args).validate()
    spec_file = tmp_path / "cell.toml"
    spec.dump(spec_file)

    outs = {}
    for tag, extra in (("spec_eager", []), ("spec_scan",
                                            ["--engine", "scan"])):
        p = tmp_path / f"{tag}.json"
        assert simulate.main(["--spec", str(spec_file), "--quiet",
                              "--json", str(p)] + extra) == 0
        outs[tag] = json.loads(p.read_text())

    legacy = json.loads(legacy_json.read_text())
    for tag, got in outs.items():
        assert got.pop("engine") in ("eager", "scan")
        ref = dict(legacy)
        ref.pop("engine")
        ref["spec_name"] = got["spec_name"]
        assert got == ref, tag


def test_spec_from_args_maps_all_policies():
    """Every policy's owned knobs land on the PolicySpec; everything else
    stays unset."""
    base = dict(alg="fedepm", latency="deterministic", m=8, d=500, n=14,
                rounds=2, seed=0, rho=0.5, k0=8, eps=0.0, topk=1.0,
                bits=0, error_feedback=False, quant_impl="ref",
                engine="eager", terminate=False, deadline=0.0,
                overselect=1.5, deadline_slack=2.0, ewma_beta=0.3,
                buffer_size=None, staleness_exp=None, max_concurrency=None,
                latency_sigma=0.5, latency_alpha=1.2, availability=1.0,
                trace_file=None)
    import argparse
    mk = lambda **kw: argparse.Namespace(**{**base, **kw})  # noqa: E731

    s = simulate.spec_from_args(mk(aggregation="sync"))
    assert s.policy == PolicySpec(name="sync")
    s = simulate.spec_from_args(mk(aggregation="deadline", deadline=0.01))
    assert s.policy == PolicySpec(name="deadline", deadline=0.01)
    s = simulate.spec_from_args(mk(aggregation="deadline"))  # infinite
    assert s.policy == PolicySpec(name="deadline")
    s = simulate.spec_from_args(mk(aggregation="adaptive",
                                   deadline_slack=3.0))
    assert s.policy == PolicySpec(name="adaptive", deadline_slack=3.0,
                                  ewma_beta=0.3)
    s = simulate.spec_from_args(mk(aggregation="async", buffer_size=4,
                                   max_concurrency=2))
    assert s.policy == PolicySpec(name="async", buffer_size=4,
                                  max_concurrency=2)
    s = simulate.spec_from_args(mk(aggregation="overselect"))
    assert s.policy == PolicySpec(name="overselect", overselect_factor=1.5)
    # validated mapping round-trips through files too
    s.validate()


# ---------------------------------------------------------------------------
# sweep
# ---------------------------------------------------------------------------

def test_sweep_cross_product_and_seeds():
    base = dataclasses.replace(
        FULL_SPEC, policy=PolicySpec(name="sync"), codec=CodecSpec(),
        algorithm=AlgorithmSpec(name="fedepm", rho=0.5, k0=4))
    cells = sweep(base,
                  {"algorithm.name": ["fedepm", "sfedavg"],
                   "policy": [PolicySpec(name="sync"),
                              PolicySpec(name="deadline", deadline=0.01)]},
                  seeds=[0, 1, 2])
    assert len(cells) == 2 * 2 * 3
    assert len({c.name for c in cells}) == len(cells)  # self-describing
    assert {c.seed for c in cells} == {0, 1, 2}
    # last axis fastest, seeds innermost
    assert cells[0].algorithm.name == "fedepm"
    assert cells[0].policy.name == "sync" and cells[0].seed == 0
    assert cells[1].seed == 1
    assert cells[3].policy.name == "deadline"
    assert cells[6].algorithm.name == "sfedavg"
    assert cells[-1].policy.name == "deadline"
    # every cell came back validated; a sweep injecting an invalid value
    # fails loudly
    with pytest.raises(SpecError):
        sweep(base, {"policy.buffer_size": [4]})
    with pytest.raises(SpecError, match="empty"):
        sweep(base, {"algorithm.name": []})


def test_sweep_cell_name_value_formatting():
    """Cell-name segments pin a readable value formatting: floats render
    shortest-within-12-significant-digits (no 0.30000000000000004 from
    binary float artifacts), bools as true/false, ints verbatim, sub-spec
    values by their .name."""
    base = dataclasses.replace(
        FULL_SPEC, policy=PolicySpec(name="sync"), codec=CodecSpec(),
        algorithm=AlgorithmSpec(name="fedepm", rho=0.5, k0=4))
    assert 0.1 * 3 != 0.3  # the binary artifact the formatting absorbs
    cells = sweep(base, {"algorithm.rho": [0.1 * 3, 0.25]})
    assert [c.name for c in cells] == [
        "test/full/algorithm.rho=0.3", "test/full/algorithm.rho=0.25"]
    cells = sweep(base, {"algorithm.k0": [4, 16]})
    assert [c.name for c in cells] == [
        "test/full/algorithm.k0=4", "test/full/algorithm.k0=16"]
    cells = sweep(base, {"engine.terminate": [False, True]})
    assert [c.name for c in cells] == [
        "test/full/engine.terminate=false",
        "test/full/engine.terminate=true"]
    cells = sweep(base, {"policy": [PolicySpec(name="sync"),
                                    PolicySpec(name="deadline",
                                               deadline=0.01)]})
    assert [c.name for c in cells] == [
        "test/full/policy=sync", "test/full/policy=deadline"]


def test_replace_dotted_paths():
    s = FULL_SPEC.replace(**{"policy.buffer_size": 5, "seed": 9})
    assert s.policy.buffer_size == 5 and s.seed == 9
    assert FULL_SPEC.policy.buffer_size == 3  # frozen original untouched
    with pytest.raises(SpecError, match="unknown spec section"):
        FULL_SPEC.replace(**{"polcy.buffer_size": 5})
    # misspelled FIELD names are SpecError too, never a raw TypeError
    with pytest.raises(SpecError, match="unknown field"):
        FULL_SPEC.replace(**{"policy.bufer_size": 5})
    with pytest.raises(SpecError, match="unknown spec field"):
        FULL_SPEC.replace(sed=9)


def test_sweep_section_axis_names_stay_unique():
    """Two sub-spec axis values sharing one .name (e.g. two topk_quant
    CodecSpecs) must not collide in cell names -- artifacts keyed by name
    would silently overwrite each other."""
    base = dataclasses.replace(
        FULL_SPEC, policy=PolicySpec(name="sync"), codec=CodecSpec(),
        algorithm=AlgorithmSpec(name="fedepm", rho=0.5, k0=4))
    cells = sweep(base, {"codec": [CodecSpec(topk_frac=0.5, bits=8),
                                   CodecSpec(topk_frac=0.25, bits=8)]})
    assert len({c.name for c in cells}) == 2
    assert cells[0].codec.topk_frac == 0.5
    assert cells[1].codec.topk_frac == 0.25


def test_train_spec_rejects_mesh_flags(capsys):
    """train.py --spec enforces the same no-silently-ignored-flags rule
    as simulate.py for the mesh-path flags."""
    from repro.launch import train
    spec_file = str(SPECS_DIR / "lm_federated.toml")
    with pytest.raises(SystemExit) as exc:
        train.main(["--spec", spec_file, "--devices", "8"])
    assert exc.value.code == 2
    assert "cannot be combined with --spec" in capsys.readouterr().err
    with pytest.raises(SystemExit) as exc:
        train.main(["--spec", spec_file, "--arch", "xlstm-125m"])
    assert exc.value.code == 2


def test_sim_knob_defaults_track_simconfig():
    """The builder's unset-knob fallbacks are SimConfig's own dataclass
    defaults -- one source of truth (a default changed in sim/server.py
    propagates to spec-built runs and the CLI's unset test)."""
    import dataclasses as dc

    from repro.sim import SimConfig
    from repro.spec.build import SIM_KNOB_DEFAULTS
    assert SIM_KNOB_DEFAULTS == {
        f.name: f.default for f in dc.fields(SimConfig)}
    assert simulate._KNOB_DEFAULTS["overselect"] \
        == SIM_KNOB_DEFAULTS["overselect_factor"]


# ---------------------------------------------------------------------------
# totality: any valid spec builds (hypothesis; optional like the kernel
# property tests)
# ---------------------------------------------------------------------------

if hypothesis is not None:
    _spec_strategy = st.builds(
        ExperimentSpec,
        seed=st.integers(0, 3),
        task=st.just(TaskSpec(kind="logreg", d=200, n=14, m=6)),
        algorithm=st.builds(
            AlgorithmSpec,
            name=st.sampled_from(["fedepm", "sfedavg", "sfedprox"]),
            rho=st.sampled_from([0.34, 0.5, 1.0]),
            k0=st.integers(1, 3),
            eps_dp=st.sampled_from([0.0, 0.5])),
        fleet=st.builds(
            FleetSpec,
            kind=st.sampled_from(["synthetic", "uniform"]),
            latency=st.sampled_from(["deterministic", "lognormal",
                                     "pareto"])),
        policy=st.one_of(
            st.just(PolicySpec(name="sync")),
            st.builds(PolicySpec, name=st.just("deadline"),
                      deadline=st.sampled_from([0.001, 1.0])),
            st.builds(PolicySpec, name=st.just("adaptive"),
                      deadline_slack=st.sampled_from([1.5, 3.0])),
            st.builds(PolicySpec, name=st.just("async"),
                      buffer_size=st.integers(0, 3),
                      max_concurrency=st.integers(0, 4))),
        codec=st.one_of(
            st.just(CodecSpec()),
            st.builds(CodecSpec, topk_frac=st.sampled_from([0.5, 1.0]),
                      bits=st.sampled_from([0, 4, 8]),
                      error_feedback=st.booleans())),
        engine=st.builds(EngineSpec,
                         name=st.sampled_from(["eager", "scan"]),
                         rounds=st.integers(1, 2)))

    @hypothesis.settings(deadline=None, max_examples=25,
                         suppress_health_check=[
                             hypothesis.HealthCheck.too_slow])
    @hypothesis.given(spec=_spec_strategy)
    def test_any_valid_spec_builds(spec):
        """Hypothesis rule: a spec that passes validate() always builds
        (and the round-trip of that spec is exact). Invalid combinations
        the strategy can generate (EF without lossy codec) must be
        rejected by the same gate -- never fail later in the builder."""
        try:
            spec.validate()
        except SpecError:
            return  # rejected up front is fine; building must not crash
        handle = spec.build()
        assert handle.sim.cfg.m == spec.task.m
        assert ExperimentSpec.from_dict(spec.to_dict()) == spec
