"""Async client-level dispatch engine, staleness weighting, adaptive
deadlines, trace-driven device profiles, and codec error feedback
(repro.sim beyond-paper policies)."""
import json
import math
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, fedepm, participation
from repro.core.tasks import make_logistic_loss
from repro.data import synth
from repro.data.partition import partition_iid
from repro.sim import (
    AdaptiveDeadlines,
    CodecConfig,
    FedSim,
    LatencyTrace,
    SimConfig,
    ef_roundtrip,
    make_profiles,
    round_arrivals,
    uniform_profiles,
)

M = 16
N = 14
FIXTURES = pathlib.Path(__file__).parent / "fixtures"
TRACE_CSV = FIXTURES / "device_trace.csv"


@pytest.fixture(scope="module")
def task():
    X, y = synth.adult_like(d=4000, n=N, seed=0)
    batches = jax.tree_util.tree_map(jnp.asarray,
                                     partition_iid(X, y, m=M, seed=0))
    return batches, make_logistic_loss()


def _tree_equal(a, b) -> bool:
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


def _cfg(**kw):
    kw.setdefault("eps_dp", 0.0)
    return fedepm.FedEPMConfig.paper_defaults(m=M, rho=0.5, k0=4, **kw)


# ---------------------------------------------------------------------------
# staleness weighting
# ---------------------------------------------------------------------------

def test_staleness_weight_units():
    """gamma(0) must be EXACTLY 1 (the bit-for-bit sync recovery hinges on
    it), monotone decreasing in s, and exp=0 disables down-weighting."""
    assert participation.staleness_weight(0, 0.5) == 1.0
    assert participation.staleness_weight(0, 2.0) == 1.0
    g = [participation.staleness_weight(s, 0.5) for s in range(5)]
    assert all(a > b for a, b in zip(g, g[1:]))
    assert participation.staleness_weight(7, 0.0) == 1.0
    # FedBuff's 1/sqrt(1+s) convention at exp=1/2
    assert participation.staleness_weight(3, 0.5) == pytest.approx(0.5)


def test_async_buffer_cohort_is_sync_bitforbit(task):
    """Acceptance criterion: buffer = cohort size + zero staleness (full
    availability, deterministic latency) reproduces the synchronous
    trajectory bit-for-bit, DP noise stream included."""
    batches, loss = task
    cfg = _cfg(eps_dp=0.1, sensitivity_clip=1.0)
    s0 = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(N), cfg)

    step = jax.jit(lambda s: fedepm.fedepm_round(s, batches, loss, cfg))
    sref = s0
    for _ in range(6):
        sref, _ = step(sref)

    sim = FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
                 loss_fn=loss, sim=SimConfig(policy="async"))
    sim.run(6)

    assert _tree_equal(sim.state.w_tau, sref.w_tau)
    assert _tree_equal(sim.state.W, sref.W)
    assert _tree_equal(sim.state.Z, sref.Z)
    assert int(sim.state.k) == int(sref.k)
    assert np.array_equal(np.asarray(sim.state.key), np.asarray(sref.key))
    # every contribution merged fresh: zero staleness throughout
    assert all(m.staleness_max == 0 for m in sim.metrics)
    assert all(m.n_aggregated == 8 for m in sim.metrics)  # rho*m

    # the async event clock must equal the sync round clock too
    sync = FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
                  loss_fn=loss, sim=SimConfig(policy="sync"))
    sync.run(6)
    assert sim.t == pytest.approx(sync.t)


def test_async_small_buffer_staleness_and_progress(task):
    """buffer < cohort under heavy-tail latency: aggregations interleave
    cohorts (staleness > 0 appears), versions advance per event, the
    objective still descends, and uploads are billed per merge."""
    batches, loss = task
    cfg = _cfg()
    s0 = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(N), cfg)
    sim = FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
                 loss_fn=loss, profiles=make_profiles(M, seed=3),
                 sim=SimConfig(policy="async", buffer_size=4,
                               latency="pareto", latency_alpha=1.1, seed=7))
    sim.run(12)
    assert sim._version == 12
    assert all(m.n_aggregated == 4 for m in sim.metrics)
    assert max(m.staleness_max for m in sim.metrics) >= 1
    assert sim.ledger.total_up == 12 * 4 * N * 4  # 4 fp32 uploads per event
    f = float(fedepm.global_objective(loss, sim.state.w_tau, batches)) / M
    assert f < math.log(2.0)  # descended from f(0) = ln 2
    # simulated time is strictly increasing across events
    ts = [m.t_total for m in sim.metrics]
    assert all(b >= a for a, b in zip(ts, ts[1:]))


def test_async_all_offline_abandons(task):
    """An unreachable fleet: the step gives up after its dry dispatches,
    charges the broadcasts, and leaves the algorithm state untouched."""
    batches, loss = task
    cfg = _cfg()
    s0 = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(N), cfg)
    # the scalar make_profiles arg rejects 0 (documented domain (0, 1]),
    # so zero out the availability array directly
    import dataclasses
    sim = FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
                 loss_fn=loss,
                 profiles=dataclasses.replace(make_profiles(M, seed=1),
                                              availability=np.zeros(M)),
                 sim=SimConfig(policy="async", seed=2))
    m = sim.step()
    assert m.abandoned and m.n_aggregated == 0
    assert m.n_dropped == m.n_contacted > 0
    assert _tree_equal(sim.state.W, s0.W)
    assert np.array_equal(np.asarray(sim.state.key), np.asarray(s0.key))
    assert sim.ledger.total_down > 0
    assert sim.ledger.total_up == 0


def test_async_rejects_bad_buffer(task):
    batches, loss = task
    cfg = _cfg()
    s0 = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(N), cfg)
    with pytest.raises(ValueError, match="buffer_size"):
        FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
               loss_fn=loss, sim=SimConfig(policy="async", buffer_size=-1))
    with pytest.raises(ValueError, match="policy"):
        FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
               loss_fn=loss, sim=SimConfig(policy="fedbuff"))


# ---------------------------------------------------------------------------
# adaptive per-client deadlines
# ---------------------------------------------------------------------------

def test_adaptive_ewma_converges_deterministic(task):
    """Under deterministic latencies the EWMA locks onto each client's true
    report time, cutoffs are finite for every observed client, nobody is
    dropped (slack > 1), and the trajectory is bit-for-bit sync."""
    batches, loss = task
    cfg = _cfg()
    s0 = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(N), cfg)
    profiles = make_profiles(M, seed=5)
    sim = FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
                 loss_fn=loss, profiles=profiles,
                 sim=SimConfig(policy="adaptive"))
    sync = FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
                  loss_fn=loss, profiles=profiles,
                  sim=SimConfig(policy="sync"))
    sim.run(6)
    sync.run(6)
    assert _tree_equal(sim.state.W, sync.state.W)
    assert _tree_equal(sim.state.Z, sync.state.Z)
    assert sum(m.n_dropped for m in sim.metrics) == 0
    assert sim.t == pytest.approx(sync.t)

    # deterministic latency => arrivals are a fixed function of the profile;
    # every client selected at least once must have ewma == its true time
    truth = round_arrivals(profiles, np.random.default_rng(0),
                           lambda rng, m: np.ones(m),
                           work_flops=sim._work,
                           down_bytes=sim.down_bytes_per_client,
                           up_bytes=sim.up_bytes_per_client)
    seen = np.isfinite(sim.deadlines.ewma)
    assert seen.any()
    np.testing.assert_allclose(sim.deadlines.ewma[seen], truth[seen],
                               rtol=1e-12)
    assert np.isfinite(sim.deadlines.cutoffs()[seen]).all()


def test_adaptive_tracker_censors_and_drops_outliers():
    """Unit-level tracker semantics: cutoffs budget slack*ewma, a straggler
    past its budget is dropped by arrival_mask's per-client deadline path,
    and its (censored) observation is the budget actually waited."""
    tr = AdaptiveDeadlines(4, beta=0.5, slack=2.0)
    cand = np.ones(4, bool)
    assert np.isinf(tr.cutoffs()).all()          # no evidence yet
    tr.observe(cand, np.array([1.0, 1.0, 1.0, np.inf]))
    np.testing.assert_allclose(tr.cutoffs()[:3], 2.0)
    assert np.isinf(tr.cutoffs()[3])             # offline: still unobserved

    # client 2 stalls at 10s: per-client mask drops exactly it
    arr = np.array([1.0, 1.5, 10.0, 1.0])
    mask = participation.arrival_mask(jnp.asarray(cand), jnp.asarray(arr),
                                      jnp.asarray(tr.cutoffs()))
    assert mask.tolist() == [True, True, False, True]

    tr.observe(cand, arr)
    # censored: the server only waited 2.0 for client 2, not 10.0
    assert tr.ewma[2] == pytest.approx(0.5 * 1.0 + 0.5 * 2.0)
    # client 3's first finite observation seeds its EWMA directly
    assert tr.ewma[3] == pytest.approx(1.0)


def test_adaptive_validation():
    with pytest.raises(ValueError, match="slack"):
        AdaptiveDeadlines(4, slack=0.5)
    with pytest.raises(ValueError, match="beta"):
        AdaptiveDeadlines(4, beta=0.0)


# ---------------------------------------------------------------------------
# codec error feedback
# ---------------------------------------------------------------------------

def test_ef_roundtrip_drains_static_residual():
    """bits=0 top-k EF on a FIXED upload: each pass transmits the largest
    remaining residual coordinates exactly, so after ceil(1/frac) passes
    the shared memory equals the upload BIT-FOR-BIT -- the contraction the
    memoryless codec cannot achieve (it forgets the residual each pass)."""
    key = jax.random.PRNGKey(0)
    z = {"w": jax.random.normal(key, (3, 8, 5))}
    codec = CodecConfig(topk_frac=0.25, bits=0, error_feedback=True)
    h = jax.tree_util.tree_map(jnp.zeros_like, z)
    passes = math.ceil(1.0 / codec.topk_frac)
    errs = []
    for t in range(passes):
        h = ef_roundtrip(z, h, jax.random.fold_in(key, t), codec)
        errs.append(max(float(jnp.max(jnp.abs(a - b)))
                        for a, b in zip(jax.tree_util.tree_leaves(h),
                                        jax.tree_util.tree_leaves(z))))
    assert all(b <= a for a, b in zip(errs, errs[1:]))  # monotone drain
    assert _tree_equal(h, z)                             # fully drained


def test_ef_dense_raw_is_identity(task):
    """topk=1, bits=0 + EF: the residual goes over the wire exactly, so the
    simulated trajectory equals the codec-free one bit-for-bit."""
    batches, loss = task
    cfg = _cfg()
    s0 = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(N), cfg)
    plain = FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
                   loss_fn=loss, sim=SimConfig(policy="sync"))
    ef = FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
                loss_fn=loss,
                sim=SimConfig(policy="sync",
                              codec=CodecConfig(topk_frac=1.0, bits=0,
                                                error_feedback=True)))
    plain.run(4)
    ef.run(4)
    assert _tree_equal(plain.state.Z, ef.state.Z)
    assert _tree_equal(plain.state.W, ef.state.W)


def test_ef_closes_compression_gap(task):
    """The contraction criterion: with an aggressive codec the EF run ends
    much closer to the uncompressed objective than the memoryless run --
    the memoryless bias plateaus, the EF residual drains as the iterates
    stabilise."""
    batches, loss = task
    cfg = _cfg()
    s0 = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(N), cfg)

    def final_f(codec):
        sim = FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
                     loss_fn=loss, sim=SimConfig(policy="sync", codec=codec))
        sim.run(20)
        return float(fedepm.global_objective(
            loss, sim.state.w_tau, batches)) / M

    f_raw = final_f(None)
    f_mem = final_f(CodecConfig(topk_frac=0.25, bits=0))
    f_ef = final_f(CodecConfig(topk_frac=0.25, bits=0, error_feedback=True))
    gap_mem = abs(f_mem - f_raw)
    gap_ef = abs(f_ef - f_raw)
    assert gap_ef < 0.5 * gap_mem
    assert f_ef < math.log(2.0)  # and it actually descended


def test_ef_works_in_async_mode(task):
    """EF + async compose: memory rows update per merged contribution and
    the compressed async run still descends."""
    batches, loss = task
    cfg = _cfg()
    s0 = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(N), cfg)
    sim = FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
                 loss_fn=loss, profiles=make_profiles(M, seed=3),
                 sim=SimConfig(policy="async", buffer_size=4,
                               latency="pareto", latency_alpha=1.2, seed=9,
                               codec=CodecConfig(topk_frac=0.5, bits=8,
                                                 error_feedback=True)))
    sim.run(10)
    f = float(fedepm.global_objective(loss, sim.state.w_tau, batches)) / M
    assert f < math.log(2.0)
    # the EF memory departed from its all-zeros init for merged clients
    h0 = jax.tree_util.tree_map(jnp.zeros_like, s0.Z)
    assert not _tree_equal(sim._H, h0)
    # compressed uploads billed at the encoded size
    assert 0 < sim.ledger.total_up < 10 * 4 * N * 4


# ---------------------------------------------------------------------------
# client-level dispatch: concurrency caps, per-client scheduling
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("cap", [8, 16, 0])  # cohort = rho*m = 8; 0 = inf
def test_async_concurrency_at_least_cohort_is_sync_bitforbit(task, cap):
    """Acceptance criterion: max_concurrency >= cohort + buffer = cohort
    reproduces the synchronous trajectory bit-for-bit -- key, clock, and
    every state leaf."""
    batches, loss = task
    cfg = _cfg(eps_dp=0.1, sensitivity_clip=1.0)
    s0 = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(N), cfg)

    step = jax.jit(lambda s: fedepm.fedepm_round(s, batches, loss, cfg))
    sref = s0
    for _ in range(5):
        sref, _ = step(sref)

    sim = FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
                 loss_fn=loss,
                 sim=SimConfig(policy="async", max_concurrency=cap))
    sync = FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
                  loss_fn=loss, sim=SimConfig(policy="sync"))
    sim.run(5)
    sync.run(5)
    for leaf_sim, leaf_ref in zip(jax.tree_util.tree_leaves(sim.state),
                                  jax.tree_util.tree_leaves(sref)):
        assert np.array_equal(np.asarray(leaf_sim), np.asarray(leaf_ref))
    assert sim.t == sync.t  # the event clock too, exactly


def test_async_baseline_buffer_cohort_is_sync_bitforbit(task):
    """The baselines run under the same client-level engine: at buffer =
    cohort the async trajectory (incl. key) is bit-for-bit the sync one --
    the agg_mask anchor degenerates to eq. (34)'s selected mean."""
    batches, loss = task
    for alg, rnd in (("sfedavg", baselines.sfedavg_round),
                     ("sfedprox", baselines.sfedprox_round)):
        cfg = baselines.BaselineConfig(m=M, k0=4, rho=0.5, eps_dp=0.0)
        s0 = baselines.init_state(jax.random.PRNGKey(1), jnp.zeros(N), cfg)
        step = jax.jit(lambda s, rnd=rnd, cfg=cfg: rnd(s, batches, loss, cfg))
        sref = s0
        for _ in range(4):
            sref, _ = step(sref)
        sim = FedSim(alg=alg, cfg=cfg, state=s0, batches=batches,
                     loss_fn=loss, sim=SimConfig(policy="async"))
        sim.run(4)
        for a, b in zip(jax.tree_util.tree_leaves(sim.state),
                        jax.tree_util.tree_leaves(sref)):
            assert np.array_equal(np.asarray(a), np.asarray(b)), alg


def test_async_concurrency_cap_is_respected_and_differs(task):
    """cap < cohort: never more than `cap` clients in flight, dispatches
    trickle (round-function calls outnumber cohort draws), staleness
    appears, the objective still descends, and the trajectory differs from
    the uncapped one (later clients see fresher broadcasts)."""
    batches, loss = task
    cfg = _cfg()
    s0 = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(N), cfg)

    def build(cap):
        return FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
                      loss_fn=loss, profiles=make_profiles(M, seed=3),
                      sim=SimConfig(policy="async", buffer_size=4,
                                    max_concurrency=cap, latency="pareto",
                                    latency_alpha=1.1, seed=7))

    capped = build(3)
    max_seen = 0
    for _ in range(10):
        capped.step()
        assert capped._n_inflight <= 3
        max_seen = max(max_seen, capped._n_inflight)
    assert max_seen > 0
    assert capped._version == 10
    assert max(m.staleness_max for m in capped.metrics) >= 1
    f = float(fedepm.global_objective(loss, capped.state.w_tau, batches)) / M
    assert f < math.log(2.0)

    uncapped = build(0)
    uncapped.run(10)
    assert not _tree_equal(capped.state.w_tau, uncapped.state.w_tau)


def test_async_capped_run_is_deterministic(task):
    """Two sims with identical SimConfig produce identical trajectories,
    clocks and ledgers (the event engine has no hidden entropy)."""
    batches, loss = task
    cfg = _cfg()
    s0 = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(N), cfg)

    def run():
        sim = FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
                     loss_fn=loss, profiles=make_profiles(M, seed=4),
                     sim=SimConfig(policy="async", buffer_size=3,
                                   max_concurrency=2, latency="lognormal",
                                   seed=11))
        sim.run(8)
        return sim

    a, b = run(), run()
    assert _tree_equal(a.state, b.state)
    assert a.t == b.t
    assert np.array_equal(a.ledger.up, b.ledger.up)
    assert np.array_equal(a.ledger.down, b.ledger.down)
    assert [m.t_round for m in a.metrics] == [m.t_round for m in b.metrics]


def test_async_rejects_bad_concurrency(task):
    batches, loss = task
    cfg = _cfg()
    s0 = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(N), cfg)
    with pytest.raises(ValueError, match="max_concurrency"):
        FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
               loss_fn=loss,
               sim=SimConfig(policy="async", max_concurrency=-1))


def test_baseline_agg_mask_hook(task):
    """Core-level contract of the async anchor: agg_mask defaults to the
    participation mask (eq. (34) unchanged) and a wider anchor changes only
    the broadcast point, not who uploads."""
    batches, loss = task
    cfg = baselines.BaselineConfig(m=M, k0=2, rho=0.5, eps_dp=0.0)
    s0 = baselines.init_state(jax.random.PRNGKey(2), jnp.zeros(N), cfg)
    # advance past init (where all Z rows coincide and every mean is equal)
    for _ in range(2):
        s0, _ = baselines.sfedavg_round(s0, batches, loss, cfg)
    mask = baselines.default_round_mask(s0, cfg)
    s_def, _ = baselines.sfedavg_round(s0, batches, loss, cfg, mask=mask)
    s_same, _ = baselines.sfedavg_round(s0, batches, loss, cfg, mask=mask,
                                        agg_mask=mask)
    assert _tree_equal(s_def, s_same)
    wide = jnp.ones((M,), bool)
    s_wide, met = baselines.sfedavg_round(s0, batches, loss, cfg, mask=mask,
                                          agg_mask=wide)
    assert not _tree_equal(s_def.w_tau, s_wide.w_tau)
    assert np.array_equal(np.asarray(met.selected), np.asarray(mask))
    # non-participants carry state through either way (eq. (22))
    W_wide = np.asarray(jax.tree_util.tree_leaves(s_wide.W)[0])
    W_0 = np.asarray(jax.tree_util.tree_leaves(s0.W)[0])
    sel = np.asarray(mask)
    assert np.array_equal(W_wide[~sel], W_0[~sel])


# ---------------------------------------------------------------------------
# trace-driven device profiles
# ---------------------------------------------------------------------------

def test_trace_loads_csv_fixture():
    tr = LatencyTrace.from_csv(TRACE_CSV)
    assert tr.n_entries == 18
    assert "pixel-6a" in tr.device
    assert (tr.speed > 0).all() and (tr.availability <= 1.0).all()
    # load() dispatches on extension
    tr2 = LatencyTrace.load(str(TRACE_CSV))
    assert tr2.device == tr.device


def test_trace_loads_json(tmp_path):
    rows = [{"device": "a", "speed": 1.0, "bw_up": 1e6, "bw_down": 1e7},
            {"device": "b", "speed": 0.5, "bw_up": 5e5, "bw_down": 5e6,
             "availability": 0.8}]
    p = tmp_path / "trace.json"
    p.write_text(json.dumps({"entries": rows}))
    tr = LatencyTrace.load(str(p))
    assert tr.n_entries == 2
    assert tr.availability[0] == 1.0          # optional field defaults
    assert tr.availability[1] == 0.8
    p2 = tmp_path / "bare.json"
    p2.write_text(json.dumps(rows))           # bare-list form
    assert LatencyTrace.from_json(p2).n_entries == 2


def test_trace_validation(tmp_path):
    with pytest.raises(ValueError, match="empty"):
        LatencyTrace.from_rows([])
    with pytest.raises(ValueError, match="missing required"):
        LatencyTrace.from_rows([{"device": "x", "speed": 1.0}])
    with pytest.raises(ValueError, match="finite"):
        LatencyTrace.from_rows([{"speed": -1.0, "bw_up": 1e6,
                                 "bw_down": 1e6}])
    with pytest.raises(ValueError, match="availability"):
        LatencyTrace.from_rows([{"speed": 1.0, "bw_up": 1e6, "bw_down": 1e6,
                                 "availability": 1.5}])
    with pytest.raises(ValueError, match="unknown trace format"):
        LatencyTrace.load(str(tmp_path / "trace.txt"))


def test_trace_resampling_assignment():
    tr = LatencyTrace.from_csv(TRACE_CSV)
    # fleet within the trace: distinct device per client, deterministic
    idx = tr.assign(10, seed=0)
    assert len(set(idx.tolist())) == 10
    assert np.array_equal(idx, tr.assign(10, seed=0))
    assert not np.array_equal(idx, tr.assign(10, seed=1))
    # fleet larger than the trace: bootstrap
    big = tr.assign(100, seed=0)
    assert len(big) == 100 and big.max() < tr.n_entries
    with pytest.raises(ValueError, match="without replacement"):
        tr.assign(100, seed=0, replace=False)
    prof = tr.sample_profiles(12, seed=3)
    assert prof.m == 12
    # each client's profile is literally a trace row
    for j in range(12):
        row = np.flatnonzero(np.isclose(tr.speed, prof.speed[j]))
        assert row.size >= 1


def test_trace_profiles_drive_async_sim(task):
    """End-to-end: a trace-resampled fleet under client-level async
    dispatch descends and produces heterogeneous arrival times."""
    batches, loss = task
    cfg = _cfg()
    s0 = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(N), cfg)
    prof = LatencyTrace.from_csv(TRACE_CSV).sample_profiles(M, seed=0)
    sim = FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
                 loss_fn=loss, profiles=prof,
                 sim=SimConfig(policy="async", buffer_size=4,
                               max_concurrency=6, seed=2))
    sim.run(8)
    f = float(fedepm.global_objective(loss, sim.state.w_tau, batches)) / M
    assert f < math.log(2.0)
    durs = [m.t_round for m in sim.metrics if not m.abandoned]
    assert len(set(durs)) > 1  # heterogeneous fleet: event gaps vary


def test_async_uniform_fleet_event_times(task):
    """Deterministic homogeneous fleet: every aggregation event waits for a
    full fresh cohort, so event times step by one round-trip each."""
    batches, loss = task
    cfg = _cfg()
    s0 = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(N), cfg)
    sim = FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
                 loss_fn=loss, profiles=uniform_profiles(M),
                 sim=SimConfig(policy="async"))
    sim.run(3)
    durs = [m.t_round for m in sim.metrics]
    assert durs[0] > 0
    assert durs[1] == pytest.approx(durs[0])
    assert durs[2] == pytest.approx(durs[0])
