"""Federated systems runtime (repro.sim): exactness vs core/, aggregation
policies over simulated time, arrival-aware masks, and the byte ledger."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, fedepm, participation
from repro.core.tasks import make_logistic_loss
from repro.data import synth
from repro.data.partition import partition_iid
from repro.sim import (
    CodecConfig,
    FedSim,
    SimConfig,
    client_work_flops,
    make_latency_model,
    make_profiles,
    round_arrivals,
    uniform_profiles,
)

M = 16
N = 14


@pytest.fixture(scope="module")
def task():
    X, y = synth.adult_like(d=4000, n=N, seed=0)
    batches = jax.tree_util.tree_map(jnp.asarray,
                                     partition_iid(X, y, m=M, seed=0))
    return batches, make_logistic_loss()


def _tree_equal(a, b) -> bool:
    return all(np.array_equal(np.asarray(x), np.asarray(y))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


# ---------------------------------------------------------------------------
# exactness: with an infinite deadline and no codec the sim IS core/fedepm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy,kw", [
    ("sync", {}),
    ("deadline", {"deadline": math.inf}),
])
def test_sim_reproduces_fedepm_bitforbit(task, policy, kw):
    """Acceptance criterion: same masks => same states, bit-for-bit, on the
    paper logreg task (eps_dp on, so the DP noise stream is exercised too)."""
    batches, loss = task
    cfg = fedepm.FedEPMConfig.paper_defaults(m=M, rho=0.5, k0=4, eps_dp=0.1,
                                             sensitivity_clip=1.0)
    s0 = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(N), cfg)

    step = jax.jit(lambda s: fedepm.fedepm_round(s, batches, loss, cfg))
    sref = s0
    for _ in range(6):
        sref, _ = step(sref)

    sim = FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
                 loss_fn=loss, sim=SimConfig(policy=policy, **kw))
    sim.run(6)

    assert _tree_equal(sim.state.w_tau, sref.w_tau)
    assert _tree_equal(sim.state.W, sref.W)
    assert _tree_equal(sim.state.Z, sref.Z)
    assert int(sim.state.k) == int(sref.k)
    assert np.array_equal(np.asarray(sim.state.key), np.asarray(sref.key))
    # no stragglers were dropped on the way
    assert all(m.n_dropped == 0 for m in sim.metrics)


def test_sim_reproduces_sfedavg_bitforbit(task):
    batches, loss = task
    cfg = baselines.BaselineConfig(m=M, k0=4, rho=0.5, eps_dp=0.0)
    s0 = baselines.init_state(jax.random.PRNGKey(1), jnp.zeros(N), cfg)
    step = jax.jit(lambda s: baselines.sfedavg_round(s, batches, loss, cfg))
    sref = s0
    for _ in range(4):
        sref, _ = step(sref)
    sim = FedSim(alg="sfedavg", cfg=cfg, state=s0, batches=batches,
                 loss_fn=loss, sim=SimConfig(policy="sync"))
    sim.run(4)
    assert _tree_equal(sim.state.w_tau, sref.w_tau)
    assert _tree_equal(sim.state.W, sref.W)


def test_default_round_mask_matches_internal(task):
    """The exported mask hook reproduces the internal selection."""
    batches, loss = task
    cfg = fedepm.FedEPMConfig.paper_defaults(m=M, rho=0.5, k0=4, eps_dp=0.0)
    s0 = fedepm.init_state(jax.random.PRNGKey(2), jnp.zeros(N), cfg)
    mask = fedepm.default_round_mask(s0, cfg)
    s_int, met_int = fedepm.fedepm_round(s0, batches, loss, cfg)
    s_ext, met_ext = fedepm.fedepm_round(s0, batches, loss, cfg, mask=mask)
    assert np.array_equal(np.asarray(met_int.selected),
                          np.asarray(met_ext.selected))
    assert _tree_equal(s_int.W, s_ext.W)


# ---------------------------------------------------------------------------
# arrival-aware masks (core.participation)
# ---------------------------------------------------------------------------

def test_arrival_mask_deadline():
    cand = jnp.asarray([True, True, True, False])
    arr = jnp.asarray([0.5, 2.0, jnp.inf, 0.1])
    got = participation.arrival_mask(cand, arr, 1.0)
    assert got.tolist() == [True, False, False, False]
    # infinite deadline still drops offline (inf-arrival) clients
    got_inf = participation.arrival_mask(cand, arr, jnp.inf)
    assert got_inf.tolist() == [True, True, False, False]


def test_first_arrivals_mask():
    cand = jnp.asarray([True, True, True, True, False])
    arr = jnp.asarray([3.0, 1.0, 2.0, jnp.inf, 0.1])
    got = participation.first_arrivals_mask(cand, arr, 2)
    assert got.tolist() == [False, True, True, False, False]
    # fewer finite arrivals than n_keep => keep all that arrived
    got_all = participation.first_arrivals_mask(cand, arr, 4)
    assert got_all.tolist() == [True, True, True, False, False]


# ---------------------------------------------------------------------------
# policies over simulated time
# ---------------------------------------------------------------------------

def test_deadline_drops_stragglers_and_carries_state(task):
    """With a tight deadline under heavy-tail latency some candidates are
    dropped; their W rows carry through unchanged (eq. (22))."""
    batches, loss = task
    cfg = fedepm.FedEPMConfig.paper_defaults(m=M, rho=1.0, k0=4, eps_dp=0.0,
                                             sampler="full")
    s0 = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(N), cfg)
    profiles = make_profiles(M, seed=3)
    # calibrate against the sim's own work model: a 40th-percentile deadline
    # makes most draws contain both finishers and stragglers
    work = client_work_flops("fedepm", k0=cfg.k0, n_params=N,
                             d_local=4000 / M)
    rng = np.random.default_rng(0)
    lat = make_latency_model("pareto", alpha=1.1)
    arr = np.concatenate([
        round_arrivals(profiles, rng, lat, work_flops=work,
                       down_bytes=N * 4, up_bytes=N * 4)
        for _ in range(200)])
    deadline = float(np.quantile(arr, 0.4))
    sim = FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
                 loss_fn=loss, profiles=profiles,
                 sim=SimConfig(policy="deadline", deadline=deadline,
                               latency="pareto", latency_alpha=1.1, seed=4))
    prev_W = np.asarray(jax.tree_util.tree_leaves(s0.W)[0]).copy()
    m0 = sim.step()
    assert m0.n_dropped > 0                      # stragglers existed
    assert m0.n_aggregated > 0                   # but someone made it
    assert m0.n_aggregated + m0.n_dropped == m0.n_contacted
    assert m0.t_round <= deadline + 1e-12
    W1 = np.asarray(jax.tree_util.tree_leaves(sim.state.W)[0])
    sel = np.asarray(sim.last_round_metrics.selected)
    assert np.array_equal(W1[~sel], prev_W[~sel])  # dropped rows untouched
    assert not np.array_equal(W1[sel], prev_W[sel])


def test_overselect_keeps_first_arrivals(task):
    batches, loss = task
    cfg = fedepm.FedEPMConfig.paper_defaults(m=M, rho=0.5, k0=4, eps_dp=0.0)
    s0 = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(N), cfg)
    sim = FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
                 loss_fn=loss, profiles=make_profiles(M, seed=5),
                 sim=SimConfig(policy="overselect", overselect_factor=1.5,
                               latency="lognormal", seed=6))
    n_keep = math.ceil(cfg.rho * M)  # the documented first-⌈ρm⌉ rule
    for _ in range(3):
        m = sim.step()
        assert m.n_contacted == min(M, round(cfg.rho * 1.5 * M))
        assert m.n_aggregated == n_keep
        assert m.n_dropped == m.n_contacted - n_keep


def test_unavailable_clients_never_aggregate(task):
    batches, loss = task
    cfg = fedepm.FedEPMConfig.paper_defaults(m=M, rho=0.5, k0=4, eps_dp=0.0)
    s0 = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(N), cfg)
    # everyone offline: the scalar make_profiles arg rejects 0 (outside
    # its documented (0, 1] domain), so zero out the array directly
    import dataclasses
    profiles = dataclasses.replace(make_profiles(M, seed=1),
                                   availability=np.zeros(M))
    sim = FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
                 loss_fn=loss, profiles=profiles,
                 sim=SimConfig(policy="sync", seed=2))
    m = sim.step()
    assert m.abandoned and m.n_aggregated == 0
    assert _tree_equal(sim.state.W, s0.W)        # state untouched
    assert sim.ledger.total_down > 0             # broadcast was still paid
    assert sim.ledger.total_up == 0


def test_infinite_deadline_with_offline_clients(task):
    """deadline=inf + partial availability: offline clients are dropped
    (inf <= inf must not admit them), simulated time stays finite, and
    only completed uploads are billed."""
    batches, loss = task
    cfg = fedepm.FedEPMConfig.paper_defaults(m=M, rho=0.5, k0=4, eps_dp=0.0)
    s0 = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(N), cfg)
    profiles = make_profiles(M, seed=1, availability=0.6)
    sim = FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
                 loss_fn=loss, profiles=profiles,
                 sim=SimConfig(policy="deadline", deadline=math.inf,
                               seed=9))
    dense = N * 4
    saw_offline_candidate = False
    for _ in range(8):
        mm = sim.step()
        assert np.isfinite(mm.t_round) and np.isfinite(mm.t_total)
        assert mm.bytes_up == mm.n_aggregated * dense
        saw_offline_candidate |= mm.n_dropped > 0
    assert saw_offline_candidate  # the probe actually exercised offline-ness


def test_overselect_rejects_nonuniform_sampler(task):
    batches, loss = task
    cfg = fedepm.FedEPMConfig.paper_defaults(m=M, rho=0.5, k0=4, eps_dp=0.0,
                                             sampler="coverage")
    s0 = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(N), cfg)
    with pytest.raises(ValueError, match="overselect"):
        FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
               loss_fn=loss, sim=SimConfig(policy="overselect"))


# ---------------------------------------------------------------------------
# byte ledger
# ---------------------------------------------------------------------------

def test_ledger_bytes_match_tree_shapes(task):
    batches, loss = task
    cfg = fedepm.FedEPMConfig.paper_defaults(m=M, rho=0.5, k0=4, eps_dp=0.0)
    s0 = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(N), cfg)
    sim = FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
                 loss_fn=loss, sim=SimConfig(policy="sync"))
    rounds = 5
    sim.run(rounds)
    n_sel = max(1, round(cfg.rho * M))
    dense = N * 4  # fp32 logreg weights
    assert sim.ledger.total_down == rounds * n_sel * dense
    assert sim.ledger.total_up == rounds * n_sel * dense
    # per-client accounting sums to the totals
    assert sim.ledger.up.sum() == sim.ledger.total_up
    assert len(sim.ledger.rounds) == rounds


def test_codec_reduces_bytes_and_stays_close(task):
    """Compressed FedEPM: fewer uplink bytes, trajectory still descends and
    stays near the uncompressed one (dequantize-before-ENS)."""
    batches, loss = task
    cfg = fedepm.FedEPMConfig.paper_defaults(m=M, rho=0.5, k0=4, eps_dp=0.0)
    s0 = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(N), cfg)

    def final_f(codec):
        sim = FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
                     loss_fn=loss,
                     sim=SimConfig(policy="sync", codec=codec))
        sim.run(10)
        f = float(fedepm.global_objective(loss, sim.state.w_tau, batches))
        return f / M, sim.ledger.total_up

    f_raw, up_raw = final_f(None)
    f_q, up_q = final_f(CodecConfig(topk_frac=0.5, bits=8))
    assert up_q < up_raw
    assert f_q < math.log(2.0)            # still descended from f(0)=ln 2
    assert abs(f_q - f_raw) < 5e-3        # and close to uncompressed
