"""Fused scan engine (repro.sim.engine): bit-for-bit equivalence with the
eager driver across every aggregation policy, golden-trajectory regression,
donation safety, and the BENCH_engine.json schema smoke."""
import importlib.util
import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import baselines, fedepm
from repro.core.tasks import make_logistic_loss
from repro.data import synth
from repro.data.partition import partition_iid
from repro.launch import simulate
from repro.sim import (
    CodecConfig,
    FedSim,
    SimConfig,
    make_profiles,
    run_rounds,
    run_to_objective,
)

M = 16
N = 14
FIXTURES = pathlib.Path(__file__).parent / "fixtures"
GOLDEN_NPZ = FIXTURES / "golden_sync_trajectory.npz"

POLICIES = [
    ("sync", {}),
    ("deadline", {"deadline": 0.002}),
    ("adaptive", {"deadline_slack": 1.5, "ewma_beta": 0.5}),
    ("overselect", {"overselect_factor": 1.5}),
    ("async", {"buffer_size": 4, "max_concurrency": 5}),
]


@pytest.fixture(scope="module")
def task():
    X, y = synth.adult_like(d=2000, n=N, seed=0)
    batches = jax.tree_util.tree_map(jnp.asarray,
                                     partition_iid(X, y, m=M, seed=0))
    return batches, make_logistic_loss()


def _build(task, policy, kw, *, alg="fedepm", codec=None, availability=0.9,
           eps=0.1, state=None, seed=9):
    batches, loss = task
    if alg == "fedepm":
        cfg = fedepm.FedEPMConfig.paper_defaults(
            m=M, rho=0.5, k0=2, eps_dp=eps, sensitivity_clip=1.0)
        s0 = state if state is not None else fedepm.init_state(
            jax.random.PRNGKey(0), jnp.zeros(N), cfg)
    else:
        cfg = baselines.BaselineConfig(m=M, k0=2, rho=0.5, eps_dp=eps)
        s0 = state if state is not None else baselines.init_state(
            jax.random.PRNGKey(0), jnp.zeros(N), cfg)
    sim_cfg = SimConfig(policy=policy, latency="pareto", latency_alpha=1.3,
                        seed=seed, codec=codec, **kw)
    return FedSim(alg=alg, cfg=cfg, state=s0, batches=batches, loss_fn=loss,
                  profiles=make_profiles(M, seed=5,
                                         availability=availability),
                  sim=sim_cfg)


def _assert_bitforbit(eager: FedSim, scan: FedSim):
    """Every state leaf, the key, the clock, the per-round metrics and the
    ledger totals must be IDENTICAL -- not allclose."""
    for name, a, b in zip(eager.state._fields, scan.state, eager.state):
        assert np.array_equal(np.asarray(a), np.asarray(b)), \
            f"state leaf {name!r} diverged"
    assert scan.t == eager.t
    assert scan.round_idx == eager.round_idx
    assert scan.metrics == eager.metrics
    assert scan.ledger.total_up == eager.ledger.total_up
    assert scan.ledger.total_down == eager.ledger.total_down
    np.testing.assert_array_equal(scan.ledger.up, eager.ledger.up)
    np.testing.assert_array_equal(scan.ledger.down, eager.ledger.down)


# ---------------------------------------------------------------------------
# scan == eager, bit for bit, all five policies
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy,kw", POLICIES, ids=[p for p, _ in POLICIES])
def test_scan_matches_eager_bitforbit(task, policy, kw):
    """5 fresh rounds under a heterogeneous, partially-available Pareto
    fleet with DP noise on: the scan engine's trajectory (state leaves,
    key, simulated clock, ledger) is the eager engine's, exactly. The
    async policy exercises run_rounds' event-path fallback."""
    eager = _build(task, policy, kw)
    scan = _build(task, policy, kw)
    eager.run(5)
    res = run_rounds(scan, 5)
    assert len(res.metrics) == 5
    _assert_bitforbit(eager, scan)


@pytest.mark.parametrize("policy,kw", POLICIES, ids=[p for p, _ in POLICIES])
def test_sim_metrics_schema_field_for_field(task, policy, kw):
    """Both engines build SimMetrics through the ONE constructor
    (server.make_sim_metrics): identical field sets and every field equal
    value-for-value, so the schemas cannot drift apart."""
    eager = _build(task, policy, kw)
    scan = _build(task, policy, kw)
    eager.run(4)
    run_rounds(scan, 4)
    assert len(eager.metrics) == len(scan.metrics) == 4
    for em, sm in zip(eager.metrics, scan.metrics):
        assert em._fields == sm._fields
        for field in em._fields:
            ev, sv = getattr(em, field), getattr(sm, field)
            assert type(ev) is type(sv), (policy, field)
            assert ev == sv, (policy, field, ev, sv)


def test_scan_matches_eager_baselines(task):
    """The baseline algorithms run the same scan body factory."""
    for alg in ("sfedavg", "sfedprox"):
        eager = _build(task, "deadline", {"deadline": 0.002}, alg=alg)
        scan = _build(task, "deadline", {"deadline": 0.002}, alg=alg)
        eager.run(4)
        run_rounds(scan, 4)
        _assert_bitforbit(eager, scan)


def test_scan_matches_eager_with_codec(task):
    """The codec merge is fused into the scan body; memoryless and EF
    paths must still match the eager two-dispatch structure bit-for-bit."""
    for ef in (False, True):
        codec = CodecConfig(topk_frac=0.5, bits=8, error_feedback=ef)
        eager = _build(task, "sync", {}, codec=codec, eps=0.0)
        scan = _build(task, "sync", {}, codec=codec, eps=0.0)
        eager.run(4)
        run_rounds(scan, 4)
        _assert_bitforbit(eager, scan)
        if ef:
            for a, b in zip(jax.tree_util.tree_leaves(eager._H),
                            jax.tree_util.tree_leaves(scan._H)):
                assert np.array_equal(np.asarray(a), np.asarray(b))


def test_scan_chunked_and_repeated_calls(task):
    """Chunk boundaries and back-to-back run_rounds calls are invisible:
    3+4 rounds in chunks of <=3 equals 7 eager rounds."""
    eager = _build(task, "sync", {})
    scan = _build(task, "sync", {})
    eager.run(7)
    run_rounds(scan, 3, chunk=2)
    run_rounds(scan, 4, chunk=3)
    _assert_bitforbit(eager, scan)


def test_scan_abandoned_rounds_carry_through(task):
    """Near-total unavailability: abandoned rounds must not advance the
    key/state in the scan either (the carry-through is a tree_where on the
    whole carry)."""
    eager = _build(task, "deadline", {"deadline": 0.002}, availability=0.15)
    scan = _build(task, "deadline", {"deadline": 0.002}, availability=0.15)
    eager.run(8)
    run_rounds(scan, 8)
    assert any(m.abandoned for m in eager.metrics), \
        "scenario failed to produce an abandoned round"
    _assert_bitforbit(eager, scan)


def test_scan_donation_leaves_caller_state_alive(task):
    """run_rounds snapshots the entry state before donating: the s0 the
    caller handed to FedSim must stay usable after a scan run."""
    batches, loss = task
    cfg = fedepm.FedEPMConfig.paper_defaults(m=M, rho=0.5, k0=2, eps_dp=0.0)
    s0 = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(N), cfg)
    scan = _build(task, "sync", {}, state=s0, eps=0.0)
    run_rounds(scan, 3)
    # donated-away buffers raise on use; s0 must not have been donated
    leaves = jax.tree_util.tree_leaves(s0)
    assert all(np.isfinite(np.asarray(x, np.float64)).all() for x in leaves)
    eager = _build(task, "sync", {}, state=s0, eps=0.0)
    eager.run(3)
    _assert_bitforbit(eager, scan)


def test_collect_w_tau_matches_states(task):
    """collect_w_tau returns each round's broadcast point, equal to the
    states an eager replay passes through."""
    eager = _build(task, "sync", {})
    scan = _build(task, "sync", {})
    res = run_rounds(scan, 3, collect_w_tau=True)
    assert res.w_tau.shape[0] == 3
    for t in range(3):
        eager.step()
        np.testing.assert_array_equal(res.w_tau[t],
                                      np.asarray(eager.state.w_tau))


def test_run_to_objective_hits_target(task):
    batches, loss = task
    scan = _build(task, "sync", {}, eps=0.0)
    fobj = jax.jit(lambda w: fedepm.global_objective(loss, w, batches))
    fobj_chunk = jax.jit(lambda W: jax.vmap(
        lambda w: fedepm.global_objective(loss, w, batches))(W))
    ref = _build(task, "sync", {}, eps=0.0)
    ref.run(4)
    target = float(fobj(ref.state.w_tau))
    rounds, hit, f = run_to_objective(scan, fobj_chunk, target,
                                      max_rounds=16, chunk=4)
    # the vmapped objective may sit 1 ulp off the scalar one that defined
    # the target, pushing the hit one round past the eager count
    assert hit and rounds in (4, 5) and f <= target


def test_make_scan_rounds_public_api(task):
    """core.fedepm.make_scan_rounds / core.baselines.make_scan_rounds: the
    standalone K-round scan compilers match an eager round-fn loop on the
    same mask stream, abandoned rounds carry through, and donate=True
    consumes the input state's buffers (the donation contract)."""
    batches, loss = task
    masks = np.zeros((4, M), bool)
    masks[:, ::2] = True
    masks[2] = False                      # round 2 "abandoned"
    abandoned = np.asarray([False, False, True, False])

    cfg = fedepm.FedEPMConfig.paper_defaults(m=M, rho=0.5, k0=2, eps_dp=0.1,
                                             sensitivity_clip=1.0)
    s0 = fedepm.init_state(jax.random.PRNGKey(3), jnp.zeros(N), cfg)
    # the reference loop must run JITTED: eager-vs-jit op folding differs
    # by 1 ulp (the kernels' bit-for-bit contract notes), and the scan is
    # pinned against the jitted semantics FedSim uses
    step = jax.jit(
        lambda s, mask: fedepm.fedepm_round(s, batches, loss, cfg, mask))
    ref = s0
    for t in range(4):
        if abandoned[t]:
            continue
        ref, _ = step(ref, jnp.asarray(masks[t]))
    run = fedepm.make_scan_rounds(batches, loss, cfg, donate=True)
    donated = jax.tree_util.tree_map(lambda x: jnp.array(x, copy=True), s0)
    out, mets = run(donated, jnp.asarray(masks), jnp.asarray(abandoned))
    for name, a, b in zip(s0._fields, out, ref):
        assert np.array_equal(np.asarray(a), np.asarray(b)), name
    assert np.asarray(mets.selected).shape == (4, M)  # stacked metrics
    with pytest.raises(RuntimeError, match="[Dd]onat|deleted"):
        np.asarray(jax.tree_util.tree_leaves(donated)[0]) + 0

    bcfg = baselines.BaselineConfig(m=M, k0=2, rho=0.5, eps_dp=0.0)
    b0 = baselines.init_state(jax.random.PRNGKey(4), jnp.zeros(N), bcfg)
    bstep = jax.jit(
        lambda s, mask: baselines.sfedavg_round(s, batches, loss, bcfg,
                                                mask))
    bref = b0
    for t in range(4):
        if abandoned[t]:
            continue
        bref, _ = bstep(bref, jnp.asarray(masks[t]))
    brun = baselines.make_scan_rounds(batches, loss, bcfg,
                                      baselines.sfedavg_round, donate=False)
    bout, _ = brun(b0, jnp.asarray(masks), jnp.asarray(abandoned))
    for name, a, b in zip(b0._fields, bout, bref):
        assert np.array_equal(np.asarray(a), np.asarray(b)), name


# ---------------------------------------------------------------------------
# golden-trajectory regression (scan engine on the pinned sync scenario)
# ---------------------------------------------------------------------------

def test_scan_engine_reproduces_golden_trajectory():
    """The 2-round golden NPZ (tools/regen_golden_trajectory.py) was
    generated by the EAGER engine; the scan engine must reproduce it to
    the same tolerances -- objective/clock/parameters/key/counter."""
    tool = FIXTURES.parent.parent / "tools" / "regen_golden_trajectory.py"
    spec = importlib.util.spec_from_file_location("regen_golden_eng", tool)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)

    X, y = synth.adult_like(d=mod.D, n=mod.N, seed=mod.SEED)
    batches = jax.tree_util.tree_map(
        jnp.asarray, partition_iid(X, y, m=mod.M, seed=mod.SEED))
    loss = make_logistic_loss()
    cfg = fedepm.FedEPMConfig.paper_defaults(
        m=mod.M, rho=0.5, k0=4, eps_dp=0.1, sensitivity_clip=1.0)
    s0 = fedepm.init_state(jax.random.PRNGKey(mod.SEED),
                           jnp.zeros(mod.N), cfg)
    sim = FedSim(alg="fedepm", cfg=cfg, state=s0, batches=batches,
                 loss_fn=loss,
                 profiles=make_profiles(mod.M, seed=mod.PROFILE_SEED),
                 sim=SimConfig(policy="sync", seed=mod.SEED))
    res = run_rounds(sim, mod.ROUNDS, collect_w_tau=True)

    golden = np.load(GOLDEN_NPZ)
    objective = [float(fedepm.global_objective(loss, jnp.asarray(w), batches))
                 for w in res.w_tau]
    np.testing.assert_allclose(objective, golden["objective"], rtol=1e-6)
    np.testing.assert_array_equal(
        [m.t_total for m in res.metrics], golden["t_total"])
    np.testing.assert_allclose(res.w_tau[:, :mod.HEAD],
                               golden["w_tau_head"], rtol=0, atol=1e-7)
    np.testing.assert_array_equal(np.asarray(sim.state.key),
                                  golden["key_final"])
    assert int(sim.state.k) == int(golden["k_final"])


# ---------------------------------------------------------------------------
# CLI glue
# ---------------------------------------------------------------------------

def test_cli_engine_scan_matches_eager(tmp_path):
    """--engine scan produces the exact summary --engine eager does."""
    outs = {}
    for engine in ("eager", "scan"):
        p = tmp_path / f"{engine}.json"
        rc = simulate.main([
            "--alg", "fedepm", "--aggregation", "deadline",
            "--deadline", "0.002", "--latency", "pareto",
            "--engine", engine, "--m", "8", "--d", "1000",
            "--rounds", "3", "--seed", "3", "--quiet", "--json", str(p)])
        assert rc == 0
        outs[engine] = json.loads(p.read_text())
    a, b = outs["eager"], outs["scan"]
    assert a.pop("engine") == "eager" and b.pop("engine") == "scan"
    assert a == b


# ---------------------------------------------------------------------------
# benchmark smoke (schema + scan-beats-eager)
# ---------------------------------------------------------------------------

@pytest.mark.benchmark
def test_bench_engine_quick_schema(tmp_path):
    """bench_engine --quick emits the documented BENCH_engine.json schema
    and the scan engine is at least as fast as eager (on CI hardware the
    observed factor is far above the >= 3x acceptance gate; the test only
    pins >= 1 to stay timing-robust)."""
    from benchmarks import bench_engine

    out = tmp_path / "BENCH_engine.json"
    rc = bench_engine.main(["--quick", "--json", str(out)])
    assert rc == 0
    b = json.loads(out.read_text())
    assert b["config"]["task"] == "paper_logreg"
    assert b["config"]["policy"] == "sync"
    for name in ("eager", "scan"):
        e = b["engines"][name]
        for field in ("rounds_per_sec", "wall_to_target_s",
                      "rounds_to_target", "host_syncs",
                      "host_syncs_per_round"):
            assert field in e, (name, field)
        assert e["rounds_per_sec"] > 0
    # same trajectory => same hit round, modulo a 1-ulp boundary flip of
    # the scan race's vmapped objective
    assert abs(b["engines"]["scan"]["rounds_to_target"]
               - b["engines"]["eager"]["rounds_to_target"]) <= 1
    assert b["speedup_rounds_per_sec"] >= 1.0
    assert b["engines"]["scan"]["host_syncs"] < \
        b["engines"]["eager"]["host_syncs"]
    # async cell: record/replay scan vs eager event loop, same schema
    # minus the objective race (trajectories are bit-identical)
    a = b["async"]
    assert a["config"]["policy"] == "async"
    for name in ("eager", "scan"):
        e = a["engines"][name]
        for field in ("rounds_per_sec", "host_syncs",
                      "host_syncs_per_round"):
            assert field in e, (name, field)
        assert e["rounds_per_sec"] > 0
    assert a["speedup_rounds_per_sec"] >= 1.0
    assert a["engines"]["scan"]["host_syncs"] < \
        a["engines"]["eager"]["host_syncs"]
