"""Per-architecture smoke tests (deliverable f): for each of the ten
assigned archs, instantiate the REDUCED variant, run one forward and one
FedEPM train round on CPU, assert output shapes + finiteness; plus decode
parity (prefill + step-by-step decode == full forward) per family."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import fedepm
from repro.core.tasks import make_chunked_lm_loss, make_lm_loss
from repro.models import dense as dense_mod
from repro.models import registry

ARCHS = configs.ALL_ARCHS


def _batch_for(cfg, B, T, key, lead=()):
    b = {}
    shape = lead + (B, T)
    if cfg.family == "audio":
        b["frame_embeds"] = jax.random.normal(key, shape + (cfg.d_model,))
        t_total = T
    elif cfg.family == "vlm":
        b["tokens"] = jax.random.randint(key, shape, 0, cfg.vocab)
        b["patch_embeds"] = jax.random.normal(
            jax.random.fold_in(key, 1), lead + (B, cfg.n_patches,
                                                cfg.d_model))
        t_total = T + cfg.n_patches
    else:
        b["tokens"] = jax.random.randint(key, shape, 0, cfg.vocab)
        t_total = T
    b["targets"] = jax.random.randint(jax.random.fold_in(key, 2),
                                      lead + (B, t_total), 0, cfg.vocab)
    b["loss_mask"] = jnp.ones(lead + (B, t_total), jnp.float32)
    return b, t_total


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    cfg = configs.get_reduced(arch)
    assert cfg.n_layers <= 4 and cfg.d_model <= 512 and cfg.n_experts <= 4
    model = registry.get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, T = 2, 32
    batch, t_total = _batch_for(cfg, B, T, jax.random.PRNGKey(1))
    logits = model.apply(params, batch)
    assert logits.shape == (B, t_total, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_fedepm_train_round(arch):
    """One FedEPM round over the reduced arch: the paper's technique as
    the trainer for every assigned architecture."""
    cfg = configs.get_reduced(arch)
    model = registry.get_model(cfg)
    m, B, T = 4, 2, 16
    loss = make_lm_loss(model.apply)
    fcfg = fedepm.FedEPMConfig.paper_defaults(m=m, rho=0.5, k0=2,
                                              eps_dp=0.1)
    params0 = model.init(jax.random.PRNGKey(0))
    state = fedepm.init_state(jax.random.PRNGKey(1), params0, fcfg)
    batch, _ = _batch_for(cfg, B, T, jax.random.PRNGKey(2), lead=(m,))
    new_state, metrics = jax.jit(
        lambda s, b: fedepm.fedepm_round(s, b, loss, fcfg))(state, batch)
    for leaf in jax.tree_util.tree_leaves(new_state.W):
        assert bool(jnp.all(jnp.isfinite(leaf.astype(jnp.float32))))
    assert int(metrics.selected.sum()) == 2
    assert bool(jnp.isfinite(metrics.snr))


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_parity(arch):
    """prefill(T-4) + 4 decode steps == full forward at those positions."""
    cfg = configs.get_reduced(arch)
    if cfg.family == "moe":
        # tight capacity drops tokens in the full forward but not in
        # 1-token decode -- use drop-free capacity for exact parity
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    model = registry.get_model(cfg)
    if not model.has_decode:
        pytest.skip("encoder-only: no decode path (documented skip)")
    if cfg.family == "vlm":
        pytest.skip("vlm decode parity covered via dense family")
    params = model.init(jax.random.PRNGKey(0))
    B, T = 2, 21
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
    full = model.apply(params, {"tokens": toks})
    Tp = T - 4
    lg, st = model.prefill(params, {"tokens": toks[:, :Tp]},
                           max_len=T + 4)
    np.testing.assert_allclose(
        np.asarray(lg[:, 0], np.float32), np.asarray(full[:, Tp - 1],
                                                     np.float32),
        atol=2e-2, rtol=2e-2)
    for t in range(Tp, T):
        lg, st = model.decode_step(params, st, {"tokens": toks[:, t:t + 1]})
        np.testing.assert_allclose(
            np.asarray(lg[:, 0], np.float32),
            np.asarray(full[:, t], np.float32), atol=2e-2, rtol=2e-2)


def test_chunked_loss_matches_full():
    cfg = configs.get_reduced("smollm-135m")
    model = registry.get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, T = 2, 40
    batch, _ = _batch_for(cfg, B, T, jax.random.PRNGKey(1))
    full = make_lm_loss(model.apply)(params, batch)
    from repro.models.registry import _FAMILY_MODULES
    mod = _FAMILY_MODULES[cfg.family]
    hidden = lambda p, b: mod.hidden(p, b, cfg)  # noqa: E731
    unembed = lambda h, p: dense_mod.unembed(h, p, cfg)  # noqa: E731
    chunked = make_chunked_lm_loss(hidden, unembed, chunk=16)(params, batch)
    np.testing.assert_allclose(float(chunked), float(full), rtol=1e-5)


def test_vlm_patch_prefix_changes_logits():
    cfg = configs.get_reduced("llava-next-34b")
    model = registry.get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, T = 2, 12
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T), 0, cfg.vocab)
    pe1 = jax.random.normal(jax.random.PRNGKey(2),
                            (B, cfg.n_patches, cfg.d_model))
    out1 = model.apply(params, {"tokens": toks, "patch_embeds": pe1})
    out2 = model.apply(params, {"tokens": toks, "patch_embeds": pe1 * 2.0})
    assert out1.shape[1] == T + cfg.n_patches
    # text logits attend to patches, so they must differ
    assert float(jnp.max(jnp.abs(out1[:, -1] - out2[:, -1]))) > 1e-4


def test_moe_routing_properties():
    cfg = configs.get_reduced("mixtral-8x7b")
    cfg = dataclasses.replace(cfg, capacity_factor=8.0)  # effectively dropless
    from repro.models import moe
    params = moe.init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, cfg.d_model))
    out, aux = moe.moe_mlp(x, jax.tree_util.tree_map(lambda p: p[0],
                                                     params["layers"])["moe"],
                           cfg)
    assert out.shape == x.shape
    assert float(aux["dropped"]) == 0.0
    assert float(aux["lb_loss"]) > 0.0


def test_xlstm_chunk_invariance():
    cfg = configs.get_reduced("xlstm-125m")
    model = registry.get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 19), 0, cfg.vocab)
    o1 = model.apply(params, {"tokens": toks})
    cfg2 = dataclasses.replace(cfg, ssm_chunk=4)
    o2 = registry.get_model(cfg2).apply(params, {"tokens": toks})
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), atol=2e-5)


def test_hubert_bidirectional():
    """Encoder attends to future frames: flipping a LATE frame changes
    EARLY outputs."""
    cfg = configs.get_reduced("hubert-xlarge")
    model = registry.get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    B, T = 1, 16
    fr = jax.random.normal(jax.random.PRNGKey(1), (B, T, cfg.d_model))
    out1 = model.apply(params, {"frame_embeds": fr})
    fr2 = fr.at[:, -1].multiply(3.0)
    out2 = model.apply(params, {"frame_embeds": fr2})
    assert float(jnp.max(jnp.abs(out1[:, 0] - out2[:, 0]))) > 1e-5
