"""Fault-injection subsystem (repro.sim.faults) + failure-aware runtime.

Pins the fault model's contract end to end:

  * ENGINE EQUIVALENCE -- under nonzero fault rates, every aggregation
    policy produces bit-identical states, byte ledgers, fault counters
    AND telemetry event streams between the eager and scan engines (the
    fault stream is host-side and replayed, never re-drawn);
  * FAULT PROCESS SEMANTICS -- quarantine lifecycle (offense threshold,
    release round, max-extension on re-offense), retry backoff schedule,
    duplicate dedup never double-merging (a duplicate-only fault model
    leaves the trajectory bit-identical to a fault-free run and only
    adds discarded billed bytes);
  * SPEC SURFACE -- [faults] validation rejects out-of-domain rates,
    NaN, bad retry/backoff/quarantine knobs; the zero-rate FaultSpec
    builds NO fault model; the CLI fault flags map onto the spec;
  * SATELLITE: make_profiles availability domain -- the documented
    (0, 1] range is enforced (0, negatives and NaN now raise, matching
    the trace loader's existing check).
"""
from __future__ import annotations

import collections
import dataclasses
import json
import math

import numpy as np
import pytest

from repro.launch import simulate
from repro.sim import make_profiles
from repro.sim.clients import LatencyTrace
from repro.sim.faults import FaultConfig, FaultModel, build_fault_model
from repro.spec import ExperimentSpec, FaultSpec, SpecError, TaskSpec
from repro.spec.types import TelemetrySpec

M = 16
N = 14

FAULTY = dict(drop_rate=0.15, transient_rate=0.2, corrupt_rate=0.1,
              duplicate_rate=0.15, reorder_jitter=0.002, max_retries=2)

POLICIES = [
    ("sync", {}),
    ("deadline", {"deadline": 0.05}),
    ("adaptive", {}),
    ("overselect", {}),
    ("async", {"buffer_size": 3, "max_concurrency": 4}),
]


def _spec(policy, policy_kw, engine, *, chunk=None, rounds=6, fl=FAULTY,
          telemetry=True, seed=0):
    spec = ExperimentSpec(
        task=TaskSpec(kind="logreg", m=M, n=N, d=200),
        faults=FaultSpec(**fl),
        telemetry=TelemetrySpec(enabled=telemetry),
        name="faults-test", seed=seed)
    return dataclasses.replace(
        spec,
        policy=dataclasses.replace(spec.policy, name=policy, **policy_kw),
        engine=dataclasses.replace(spec.engine, name=engine, rounds=rounds,
                                   chunk=chunk)).validate()


def _event_tuples(sim):
    return [(e.kind, e.round_idx, e.client, e.ts,
             tuple(sorted(e.attrs.items()))) for e in sim.telemetry.events]


# ---------------------------------------------------------------------------
# engine equivalence under faults
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy,kw", POLICIES, ids=[p for p, _ in POLICIES])
def test_eager_scan_bitforbit_under_faults(policy, kw):
    """Eager and scan runs of the same faulted experiment agree on the
    final state, ledger, fault counters and the FULL telemetry event
    stream -- the ISSUE's bit-for-bit acceptance bar."""
    h1 = _spec(policy, kw, "eager").build()
    s1 = h1.run()
    h2 = _spec(policy, kw, "scan", chunk=3).build()
    s2 = h2.run()
    w1, w2 = np.asarray(h1.sim.state.w_tau), np.asarray(h2.sim.state.w_tau)
    assert np.array_equal(w1, w2)
    assert h1.sim.t == h2.sim.t
    assert s1["bytes_up"] == s2["bytes_up"]
    assert s1["bytes_down"] == s2["bytes_down"]
    assert s1["faults"] == s2["faults"]
    assert s1["faults"]["upload_drops"] + s1["faults"]["retries"] > 0
    assert _event_tuples(h1.sim) == _event_tuples(h2.sim)


def test_drop_everything_async_terminates_both_engines():
    """drop_rate=1.0 under async: cohorts stay live (so the dry-dispatch
    rule never fires) but the fault-select cap bounds each step; every
    round is abandoned identically in both engines."""
    kw = {"buffer_size": 3, "max_concurrency": 4}
    fl = dict(drop_rate=1.0)
    h1 = _spec("async", kw, "eager", rounds=3, fl=fl).build()
    s1 = h1.run()
    h2 = _spec("async", kw, "scan", chunk=2, rounds=3, fl=fl).build()
    s2 = h2.run()
    assert s1["abandoned_rounds"] == s2["abandoned_rounds"] == 3
    assert s1["faults"] == s2["faults"]
    assert s1["faults"]["upload_drops"] > 0
    assert np.array_equal(np.asarray(h1.sim.state.w_tau),
                          np.asarray(h2.sim.state.w_tau))


# ---------------------------------------------------------------------------
# fault-process semantics
# ---------------------------------------------------------------------------

def test_quarantine_lifecycle():
    """Offense accounting: quarantine fires at the threshold, holds for
    quarantine_rounds, releases, and re-offense extends (never shortens)
    an active sentence."""
    cfg = FaultConfig(corrupt_rate=0.5, quarantine_after=2,
                      quarantine_rounds=3, seed=0)
    fm = FaultModel(cfg, M)
    assert fm.record_offense(4, round_idx=0) is None      # 1st offense
    until = fm.record_offense(4, round_idx=0)             # 2nd -> fires
    assert until == 0 + 1 + 3
    mask = fm.quarantine_mask(1)
    assert mask[4] and mask.sum() == 1
    assert not fm.quarantine_mask(until)[4]               # released
    assert fm.offenses[4] == 0                            # counter reset
    # re-offense during the sentence extends from the offense round
    fm.record_offense(4, round_idx=2)
    until2 = fm.record_offense(4, round_idx=2)
    assert until2 == 2 + 1 + 3 and fm.quarantined_until[4] == until2
    # a LATER sentence never shrinks an existing longer one
    fm.quarantined_until[7] = 99
    fm.record_offense(7, round_idx=1)
    fm.record_offense(7, round_idx=1)
    assert fm.quarantined_until[7] == 99
    assert fm.total_quarantines == 3


def test_backoff_schedule_and_state_roundtrip():
    cfg = FaultConfig(transient_rate=0.5, backoff_base=1e-3,
                      backoff_factor=2.0, seed=0)
    fm = FaultModel(cfg, M)
    assert fm.backoff(1) == pytest.approx(1e-3)
    assert fm.backoff(3) == pytest.approx(4e-3)
    # snapshot/restore replays the identical decision stream (the scan
    # engine's fixpoint rewinds the fault state between passes)
    snap = fm.state_snapshot()
    a = [fm.draw_outcome() for _ in range(32)]
    fm.state_restore(snap)
    b = [fm.draw_outcome() for _ in range(32)]
    assert a == b


def test_duplicates_never_double_merge():
    """A duplicate-only fault model must not change the trajectory at
    all: every duplicate is deduped before the merge, so the only effect
    is the discarded copies' billed bytes."""
    for policy, kw in (("sync", {}), ("async", {"buffer_size": 3})):
        fl = dict(duplicate_rate=0.6, reorder_jitter=0.003)
        hf = _spec(policy, kw, "eager", fl=fl).build()
        sf = hf.run()
        h0 = _spec(policy, kw, "eager",
                   fl=dict(), telemetry=True).build()
        assert h0.sim._faults is None
        s0 = h0.run()
        assert np.array_equal(np.asarray(hf.sim.state.w_tau),
                              np.asarray(h0.sim.state.w_tau))
        n_dups = sf["faults"]["duplicates_discarded"]
        assert n_dups > 0
        up_b = hf.sim.up_bytes_per_client
        assert sf["bytes_up"] - s0["bytes_up"] == pytest.approx(
            n_dups * up_b)
        assert sf["bytes_down"] == s0["bytes_down"]


def test_corrupt_payloads_screened_and_quarantined():
    """corrupt_rate=1.0: nothing ever merges, every attempt is rejected,
    and the whole fleet ends up quarantined (then nothing is contacted,
    so rounds abandon without bytes)."""
    fl = dict(corrupt_rate=1.0, quarantine_after=1, quarantine_rounds=2)
    h = _spec("sync", {}, "eager", rounds=5, fl=fl).build()
    s = h.run()
    assert s["faults"]["corrupt_rejected"] > 0
    assert s["faults"]["quarantines"] > 0
    assert s["abandoned_rounds"] > 0
    # the model parameters never moved: every payload was screened out
    assert np.array_equal(np.asarray(h.sim.state.w_tau), np.zeros(N))


# ---------------------------------------------------------------------------
# spec surface
# ---------------------------------------------------------------------------

def test_zero_rate_spec_builds_no_fault_model():
    """All-zero rates (even with non-default retry/quarantine knobs)
    build NO FaultModel: the pre-fault code path, byte-identical."""
    spec = _spec("sync", {}, "eager",
                 fl=dict(max_retries=7, quarantine_rounds=9, seed=42))
    h = spec.build()
    assert h.sim._faults is None and h.sim.sim.faults is None
    assert "faults" not in h.run()
    assert build_fault_model(None, M) is None
    assert build_fault_model(FaultConfig(), M) is None
    with pytest.raises(ValueError, match="nonzero rate"):
        FaultModel(FaultConfig(), M)


@pytest.mark.parametrize("bad,match", [
    (dict(drop_rate=1.5), r"\[faults\] drop_rate"),
    (dict(drop_rate=float("nan")), r"\[faults\] drop_rate"),
    (dict(transient_rate=-0.1), r"\[faults\] transient_rate"),
    (dict(drop_rate=0.5, transient_rate=0.4, corrupt_rate=0.2), "partition"),
    (dict(max_retries=-1), "max_retries"),
    (dict(backoff_base=0.0), "backoff_base"),
    (dict(backoff_factor=0.5), "backoff_factor"),
    (dict(reorder_jitter=-1.0), "reorder_jitter"),
    (dict(reorder_jitter=float("inf")), "reorder_jitter"),
    (dict(quarantine_after=0), "quarantine_after"),
    (dict(quarantine_rounds=0), "quarantine_rounds"),
    (dict(corrupt_mode="zap"), "corrupt_mode"),
    (dict(seed=-1), "seed"),
])
def test_fault_spec_validation_rejects(bad, match):
    spec = ExperimentSpec(task=TaskSpec(kind="logreg", m=M, n=N, d=200),
                          name="x", seed=0)
    spec = dataclasses.replace(spec, faults=FaultSpec(**bad))
    with pytest.raises(SpecError, match=match):
        spec.validate()


def test_fault_spec_toml_roundtrip(tmp_path):
    spec = _spec("sync", {}, "eager")
    f = tmp_path / "faulty.toml"
    spec.dump(f)
    assert ExperimentSpec.load(f) == spec
    assert "[faults]" in f.read_text()


def test_cli_fault_flags(tmp_path):
    """The --fault-* flags reach the fault model (summary carries the
    counters), same seed reproduces, and the flags conflict with
    --spec."""
    outs = []
    for i in range(2):
        p = tmp_path / f"run{i}.json"
        rc = simulate.main([
            "--alg", "fedepm", "--aggregation", "sync",
            "--m", "8", "--d", "400", "--rounds", "4", "--seed", "3",
            "--fault-drop", "0.2", "--fault-transient", "0.3",
            "--fault-max-retries", "1", "--fault-seed", "11",
            "--quiet", "--json", str(p)])
        assert rc == 0
        outs.append(json.loads(p.read_text()))
    assert outs[0] == outs[1]
    fl = outs[0]["faults"]
    assert fl["upload_drops"] + fl["retries"] > 0
    with pytest.raises(SystemExit):
        simulate.main(["--spec", "examples/specs/fig8_faults.toml",
                       "--fault-drop", "0.5", "--quiet"])


# ---------------------------------------------------------------------------
# satellite: availability domain (0, 1]
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("avail", [0.0, -0.5, 1.5, float("nan")])
def test_make_profiles_rejects_bad_availability(avail):
    with pytest.raises(ValueError, match="availability"):
        make_profiles(4, availability=avail)


def test_make_profiles_accepts_domain_edges():
    assert make_profiles(4, availability=1.0).availability.tolist() \
        == [1.0] * 4
    assert make_profiles(4, availability=1e-9).m == 4


@pytest.mark.parametrize("avail", ["0.0", "-1.0", "nan", "inf"])
def test_trace_loader_rejects_bad_availability(avail):
    rows = [{"speed": 1.0, "bw_up": 1e6, "bw_down": 1e7,
             "availability": avail}]
    with pytest.raises(ValueError, match="availability|finite"):
        LatencyTrace.from_rows(rows)
