"""Dry-run machinery on a small fake mesh (subprocess): lower+compile a
sample of (arch x shape) steps, exercise the artifact writer, the HLO
collective census, and the while-loop trip parser."""
import json
import os
import subprocess
import sys

import pytest

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys
sys.path.insert(0, os.environ["REPRO_SRC"])
import jax
import jax.numpy as jnp

from repro.launch.steps import build_step, Skip
from repro.launch.dryrun import collective_census, while_loop_info

mesh = jax.make_mesh((4, 2), ("data", "model"))

# Use reduced configs via monkeypatching get_config so the small mesh can
# hold them (full configs need the 256-chip mesh).
import repro.configs as configs
real_get = configs.get_config
configs.get_config = configs.get_reduced
try:
    cases = [("smollm-135m", "train_4k"), ("zamba2-1.2b", "decode_32k"),
             ("hubert-xlarge", "prefill_32k"), ("hubert-xlarge",
                                                "decode_32k"),
             ("xlstm-125m", "long_500k")]
    for arch, shape in cases:
        b = build_step(arch, shape, mesh)
        if isinstance(b, Skip):
            print(f"{arch} {shape}: SKIP {b.reason}")
            assert (arch, shape) == ("hubert-xlarge", "decode_32k")
            continue
        compiled = b.lower().compile()
        ma = compiled.memory_analysis()
        hlo = compiled.as_text()
        census = collective_census(hlo)
        trips, parents = while_loop_info(hlo)
        kinds = sorted({c["op"] for c in census})
        print(f"{arch} {shape}: ok peak={ma.temp_size_in_bytes/1e9:.2f}GB "
              f"collectives={kinds} n_while={len(trips)}")
        if shape == "train_4k":
            # the layer scan must be visible with its trip count
            assert any(t == 2 for t in trips.values()), trips
            assert census, "train step must communicate"
finally:
    configs.get_config = real_get
print("DRYRUN-SMALL-OK")
"""


@pytest.mark.slow
def test_dryrun_small_mesh():
    env = dict(os.environ)
    env["REPRO_SRC"] = os.path.join(os.path.dirname(__file__), "..", "src")
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=1800)
    assert "DRYRUN-SMALL-OK" in out.stdout, (out.stdout[-3000:],
                                             out.stderr[-5000:])


def test_census_parser_units():
    from repro.launch.dryrun import collective_census, _shape_bytes
    assert _shape_bytes("bf16[4,8]{1,0}") == 64
    assert _shape_bytes("f32[]") == 4
    hlo = """
ENTRY %main (p0: f32[16]) -> f32[16] {
  %ag = f32[16]{0} all-gather(%p0), replica_groups={}
  %ar = bf16[8,2]{1,0} all-reduce(%x), to_apply=%add
  ROOT %t = f32[16]{0} copy(%ag)
}
"""
    ops = collective_census(hlo)
    assert {o["op"] for o in ops} == {"all-gather", "all-reduce"}
    assert sum(o["bytes"] for o in ops) == 64 + 32


def test_loop_parser_units():
    from repro.launch.roofline import parse_hlo_loops
    hlo = """
%body.1 (p: s32[]) -> s32[] {
  ROOT %x = s32[] add(%p, %c)
}

%cond.1 (p: s32[]) -> pred[] {
  %c10 = s32[] constant(10)
  ROOT %cmp = pred[] compare(%p, %c10), direction=LT
}

ENTRY %main (a: s32[]) -> s32[] {
  ROOT %w = s32[] while(%a), condition=%cond.1, body=%body.1
}
"""
    trips, parents = parse_hlo_loops(hlo)
    assert trips == {"body.1": 10}
    assert parents == {"body.1": "main"}
