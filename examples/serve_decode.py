"""Serving example: prefill a batch of prompts through a (reduced) model
and decode new tokens with the ring/recurrent caches -- the same
prefill/decode_step pair the decode_32k / long_500k dry-run shapes lower.

    PYTHONPATH=src python examples/serve_decode.py --arch zamba2-1.2b
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import registry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m",
                    choices=configs.ALL_ARCHS)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--new-tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = configs.get_reduced(args.arch)
    model = registry.get_model(cfg)
    if not model.has_decode:
        print(f"{args.arch} is encoder-only; no decode path "
              f"(documented skip). Running one encode instead.")
        params = model.init(jax.random.PRNGKey(0))
        fr = jax.random.normal(jax.random.PRNGKey(1),
                               (args.batch, args.prompt_len, cfg.d_model))
        out = model.apply(params, {"frame_embeds": fr})
        print("encoded:", out.shape)
        return

    params = model.init(jax.random.PRNGKey(0))
    B, Tp = args.batch, args.prompt_len
    prompts = jax.random.randint(jax.random.PRNGKey(1), (B, Tp), 0,
                                 cfg.vocab)
    batch = {"tokens": prompts}
    if cfg.family == "vlm":
        batch["patch_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.n_patches, cfg.d_model))

    max_len = Tp + args.new_tokens + (cfg.n_patches or 0)
    prefill = jax.jit(lambda p, b: model.prefill(p, b, max_len=max_len))
    decode = jax.jit(model.decode_step, donate_argnums=1)

    t0 = time.time()
    logits, state = prefill(params, batch)
    jax.block_until_ready(logits)
    t_prefill = time.time() - t0
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]

    out_tokens = [np.asarray(tok)]
    t0 = time.time()
    for _ in range(args.new_tokens - 1):
        logits, state = decode(params, state, {"tokens": tok})
        tok = jnp.argmax(logits[:, 0], axis=-1)[:, None]
        out_tokens.append(np.asarray(tok))
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.concatenate(out_tokens, axis=1)
    print(f"arch={args.arch} prefill({Tp} toks x {B}): {t_prefill:.3f}s  "
          f"decode({args.new_tokens} toks): {t_decode:.3f}s "
          f"({args.new_tokens*B/max(t_decode,1e-9):.1f} tok/s)")
    print("generated token ids (first sequence):", gen[0][:16], "...")


if __name__ == "__main__":
    main()
