"""Paper reproduction driver: the three algorithms (FedEPM, SFedAvg,
SFedProx) head-to-head with the paper's stopping rule, reporting the five
factors (f(w)/m, CR, TCT, LCT, SNR) of Sec. VII.C.

    PYTHONPATH=src python examples/paper_repro.py [--d 45222] [--m 50]
"""
import argparse
import sys

sys.path.insert(0, ".")  # for `benchmarks` when run from repo root

from benchmarks.common import run_algorithm  # noqa: E402


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--d", type=int, default=20000,
                    help="instances (paper: 45222)")
    ap.add_argument("--m", type=int, default=50)
    ap.add_argument("--k0", type=int, default=12)
    ap.add_argument("--rho", type=float, default=0.5)
    ap.add_argument("--eps", type=float, default=0.1)
    args = ap.parse_args()

    print(f"task: adult-like d={args.d}, m={args.m}, k0={args.k0}, "
          f"rho={args.rho}, eps={args.eps}\n")
    print(f"{'alg':10s} {'f(w)/m':>10s} {'CR':>5s} {'TCT(s)':>8s} "
          f"{'LCT(ms)':>9s} {'SNR':>7s}")
    for alg in ("fedepm", "sfedavg", "sfedprox"):
        r = run_algorithm(alg, m=args.m, k0=args.k0, rho=args.rho,
                          eps=args.eps, d=args.d)
        print(f"{alg:10s} {r['f']:10.5f} {r['CR']:5d} {r['TCT']:8.2f} "
              f"{r['LCT']*1e3:9.3f} {r['SNR']:7.2f}")


if __name__ == "__main__":
    main()
