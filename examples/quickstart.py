"""Quickstart: FedEPM in ~40 lines on the paper's logistic-regression task,
then the same thing as ONE declarative experiment spec (repro.spec).

    PYTHONPATH=src python examples/quickstart.py
"""
import pathlib

import jax
import jax.numpy as jnp

from repro.core import fedepm
from repro.core.tasks import accuracy_logistic, make_logistic_loss
from repro.data import synth
from repro.data.partition import partition_iid
from repro.spec import ExperimentSpec

SPECS_DIR = pathlib.Path(__file__).resolve().parent / "specs"


def main():
    # 1. data: synthetic Adult-income stand-in, dealt to m clients
    m = 50
    X, y = synth.adult_like(d=20000, n=14, seed=0)
    batches = jax.tree_util.tree_map(
        jnp.asarray, partition_iid(X, y, m=m, seed=0))
    loss = make_logistic_loss()

    # 2. the paper's hyper-parameters (Sec. VII.B)
    cfg = fedepm.FedEPMConfig.paper_defaults(m=m, rho=0.5, k0=12,
                                             eps_dp=0.1)
    state = fedepm.init_state(jax.random.PRNGKey(0), jnp.zeros(14), cfg)
    step = jax.jit(lambda s: fedepm.fedepm_round(s, batches, loss, cfg))

    # 3. train: each round = ENS aggregation + 1 gradient/client + k0
    #    closed-form prox steps + DP-noised upload
    for r in range(40):
        state, metrics = step(state)
        if r % 5 == 0:
            f = float(fedepm.global_objective(loss, state.w_tau, batches))
            acc = float(accuracy_logistic(state.w_tau, jnp.asarray(X),
                                          jnp.asarray(y)))
            print(f"round {r:3d}  f(w)/m={f/m:.5f}  acc={acc:.3f}  "
                  f"SNR={float(metrics.snr):.2f}  "
                  f"selected={int(metrics.selected.sum())}/{m}")

    acc = float(accuracy_logistic(state.w_tau, jnp.asarray(X),
                                  jnp.asarray(y)))
    f = float(fedepm.global_objective(loss, state.w_tau, batches)) / m
    print(f"\nfinal f(w)/m={f:.5f} (regularised optimum ~0.6918), "
          f"accuracy={acc:.3f} (optimum ~0.74), eps-DP eps={cfg.eps_dp}")
    assert f < 0.6925 and acc > 0.70

    # 4. the declarative way: every bundled spec under examples/specs/ is
    #    a complete experiment description (task x algorithm x fleet x
    #    policy x codec x engine, docs/spec.md); load + validate them all,
    #    then run the cheapest one end-to-end through spec.build()
    specs = {p.name: ExperimentSpec.load(p)
             for p in sorted(SPECS_DIR.glob("*.toml"))}
    print(f"\nbundled specs: {', '.join(specs)}")
    exp = specs["golden_sync.toml"]
    summary = exp.build().run()
    print(f"spec '{exp.name}': {exp.algorithm.name}/{exp.policy.name} "
          f"x {summary['rounds']} rounds -> f/m={summary['f_final']:.5f}, "
          f"{summary['bytes_total']:.0f} wire bytes")


if __name__ == "__main__":
    main()
