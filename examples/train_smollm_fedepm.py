"""End-to-end driver: train a ~135M-parameter LM (smollm-135m, full
config by default) with FedEPM as the federated optimizer for a few
hundred communication rounds on synthetic token streams.

On this CPU container the default uses the REDUCED smollm config with a
small batch so a full run finishes in minutes; pass --full-config on a
real host for the 135M model (and --rounds 300 for the few-hundred-step
run the deliverable describes).

    PYTHONPATH=src python examples/train_smollm_fedepm.py --rounds 40
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint import save
from repro.core import fedepm
from repro.core.tasks import make_lm_loss
from repro.data.lm import federated_token_batches
from repro.models import registry


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=40)
    ap.add_argument("--m", type=int, default=4, help="clients")
    ap.add_argument("--batch", type=int, default=4, help="seqs per client")
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--k0", type=int, default=4)
    ap.add_argument("--eps", type=float, default=0.5)
    ap.add_argument("--full-config", action="store_true",
                    help="use the real 135M config (needs a big host)")
    ap.add_argument("--checkpoint", default=None)
    args = ap.parse_args()

    cfg = (configs.get_config("smollm-135m") if args.full_config
           else configs.get_reduced("smollm-135m"))
    model = registry.get_model(cfg)
    loss = make_lm_loss(model.apply)
    n_params = sum(x.size for x in jax.tree_util.tree_leaves(
        jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))))
    print(f"arch=smollm-135m ({'full' if args.full_config else 'reduced'}) "
          f"params={n_params/1e6:.1f}M  m={args.m} k0={args.k0}")

    # LM-scale FedEPM hyper-parameters (the paper's were tuned for n=14
    # logistic regression): mu0 acts as an INVERSE learning rate (the
    # prox step is ~ -g/mu), so mu0=0.05 means lr=20 -> divergence on an
    # LM; mu0=20 (lr=0.05) trains. sensitivity_clip caps the paper's
    # Delta_hat = 2||g||_1 surrogate, which otherwise scales with the
    # parameter count and overflows fp32.
    fcfg = fedepm.FedEPMConfig.paper_defaults(
        m=args.m, rho=0.5, k0=args.k0, eps_dp=args.eps,
        mu0=20.0, sensitivity_clip=1.0)
    params0 = model.init(jax.random.PRNGKey(0))
    state = fedepm.init_state(jax.random.PRNGKey(1), params0, fcfg)
    step = jax.jit(lambda s, b: fedepm.fedepm_round(s, b, loss, fcfg))

    stream = federated_token_batches(cfg.vocab, args.m, args.batch,
                                     args.seq, steps=args.rounds, seed=0)
    t0 = time.time()
    first_loss = None
    for r, raw in enumerate(stream):
        batch = jax.tree_util.tree_map(jnp.asarray, raw)
        state, metrics = step(state, batch)
        if r % 5 == 0 or r == args.rounds - 1:
            f = float(fedepm.global_objective(loss, state.w_tau, batch))
            f /= args.m
            if first_loss is None:
                first_loss = f
            print(f"round {r:4d}  loss={f:.4f}  SNR={float(metrics.snr):.2f}"
                  f"  drift={float(metrics.drift):.3e}  "
                  f"({time.time()-t0:.1f}s)")
    print(f"\nloss: {first_loss:.4f} -> {f:.4f} "
          f"({(1 - f/first_loss)*100:.1f}% reduction)")
    if args.checkpoint:
        save(args.checkpoint, state.w_tau,
             {"arch": cfg.name, "rounds": args.rounds})
        print("checkpointed aggregate model to", args.checkpoint)


if __name__ == "__main__":
    main()
